"""Logical-axis sharding for the model zoo.

Activations are annotated with *logical* names; a context-scoped rules table
maps them to physical mesh axes.  The launcher sets the rules per mesh:

    single-pod (16, 16) ("data", "model"):   batch->data,  tensor->model
    multi-pod (2, 16, 16) ("pod","data","model"): batch->(pod,data), tensor->model
    long-context decode:                      seq->data (batch is 1)

Parameter shardings are derived from leaf names via PARAM_RULES — every
parameter name in the zoo encodes its role (see models/*.py).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules() -> dict:
    return getattr(_state, "rules", {})


@contextlib.contextmanager
def logical_rules(rules: dict):
    """rules: logical name -> physical axis (str, tuple, or None)."""
    prev = getattr(_state, "rules", {})
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def resolve(*logical_names) -> P:
    rules = current_rules()
    return P(*[rules.get(n, None) for n in logical_names])


def _mesh_sizes():
    try:
        from ..compat import get_abstract_mesh

        am = get_abstract_mesh()
        return dict(am.shape) if am.axis_names else None
    except Exception:
        return None


def _fit_spec_sizes(spec: P, shape, sizes) -> P:
    """Drop sharding on dims whose size isn't divisible by the axis product."""
    if sizes is None:
        return spec
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        prod = 1
        ok = all(a in sizes for a in axes)
        for a in axes:
            prod *= sizes.get(a, 1)
        fixed.append(ax if (ok and dim % prod == 0) else None)
    return P(*fixed)


def constrain(x, *logical_names):
    """with_sharding_constraint if rules are active (no-op in smoke tests).
    Axes that don't divide the corresponding dim are dropped."""
    if not current_rules():
        return x
    spec = _fit_spec_sizes(resolve(*logical_names), x.shape, _mesh_sizes())
    return jax.lax.with_sharding_constraint(x, spec)


# --- parameter rules -------------------------------------------------------
# leaf-name -> logical axes for the *trailing* dims (a leading scan/layer dim,
# if present, is unsharded).  fsdp == the data axis, tensor == the model axis.

PARAM_RULES = {
    # embeddings
    "embedding": ("tensor", "fsdp"),        # (V, D)
    "unembed": ("fsdp", "tensor"),          # (D, V)
    "pos_embedding": (None, "fsdp"),        # (S, D)
    # attention
    "wq": ("fsdp", "tensor"),               # (D, H*hd)
    "wk": ("fsdp", "tensor"),
    "wv": ("fsdp", "tensor"),
    "wo": ("tensor", "fsdp"),               # (H*hd, D)
    # dense mlp (wi covers fused gate+up)
    "wi": ("fsdp", "tensor"),               # (D, {1,2}F)
    "wo_mlp": ("tensor", "fsdp"),           # (F, D)
    # moe — expert-parallel over the model axis; F stays unsharded (the same
    # physical axis cannot appear twice in one spec)
    "router": ("fsdp", None),               # (D, E) — E small, replicate
    "w_in_e": ("expert", "fsdp", None),     # (E, D, {1,2}F)
    "w_out_e": ("expert", None, "fsdp"),    # (E, F, D)
    # ssm / xlstm
    "w_ssm_in": ("fsdp", "tensor"),
    "w_ssm_out": ("tensor", "fsdp"),
    "conv_w": (None, "tensor"),             # (K, d_inner)
    "a_log": ("tensor",),
    "dt_bias": ("tensor",),
    "r_h": (None, "tensor"),                # sLSTM recurrent (hd, H*hd) blocks
    # norms / scalars
    "scale": (None,),
    "bias": (None,),
}


def gather_layer_params(layer_params):
    """FSDP gather INSIDE the layer-scan body.

    Constrains every weight leaf to its compute sharding with the fsdp axis
    dropped (tensor-parallel axis kept).  Placing this constraint inside the
    scan body pins the all-gather to one layer at a time — without it XLA may
    hoist the gather of the whole stacked (L, ...) parameter out of the loop,
    exploding peak memory (observed: 433 GB/device on mistral-large-123b).
    """
    rules = current_rules()
    if not rules:
        return layer_params
    sizes = _mesh_sizes()

    def f(path, leaf):
        name = getattr(path[-1], "key", getattr(path[-1], "name", "")) if path else ""
        logical = PARAM_RULES.get(name)
        if logical is None or not hasattr(leaf, "ndim"):
            return leaf
        axes = [
            (rules.get(a, None) if a not in (None, "fsdp") else None) if a else None
            for a in logical
        ]
        pad = leaf.ndim - len(axes)
        if pad < 0:
            return leaf
        spec = _fit_spec_sizes(P(*([None] * pad + axes)), leaf.shape, sizes)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(f, layer_params)


def param_spec_for(name: str, ndim: int, stacked: bool) -> P:
    rules = current_rules()
    logical = PARAM_RULES.get(name)
    if logical is None:
        # default: replicate
        return P()
    axes = [rules.get(a, None) if a else None for a in logical]
    # ndim may exceed the rule (e.g. grouped dims) — pad with None on the left
    # after the optional stacked dim
    lead = [None] if stacked else []
    pad = ndim - len(axes) - len(lead)
    return P(*(lead + [None] * pad + axes))


def fit_spec_to_mesh(spec: P, shape, mesh) -> P:
    """Drop sharding on any dim whose size isn't divisible by the mesh-axis
    product (e.g. a 51865 vocab or 4 KV heads can't split 16 ways)."""
    if mesh is None:
        return spec
    try:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    except (AttributeError, ValueError, NotImplementedError):
        sizes = dict(mesh.shape)  # AbstractMesh
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        prod = 1
        ok = True
        for a in axes:
            if a not in sizes:
                ok = False
                break
            prod *= sizes[a]
        fixed.append(ax if (ok and dim % prod == 0) else None)
    return P(*fixed)


def tree_param_specs(params_tree, mesh=None):
    """Map a pytree of arrays/ShapeDtypeStructs to PartitionSpecs by leaf name.

    A leaf is 'stacked' when its first dim is a layer-scan dim — encoded by the
    surrounding dict key 'layers'/'blocks' in its path.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = keys[-1] if keys else ""
        stacked = any(k in ("layers", "blocks", "enc_layers", "dec_layers", "mamba_layers") for k in keys[:-1])
        spec = param_spec_for(name, leaf.ndim, stacked)
        specs.append(fit_spec_to_mesh(spec, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


# canonical rule tables used by the launcher -------------------------------

def rules_single_pod() -> dict:
    return {"batch": "data", "fsdp": "data", "tensor": "model", "expert": "model", "seq": None}


def rules_multi_pod() -> dict:
    # pure data-parallel across pods: params replicated over 'pod', batch
    # sharded over (pod, data)
    return {"batch": ("pod", "data"), "fsdp": "data", "tensor": "model", "expert": "model", "seq": None}


def rules_long_context(multi_pod: bool) -> dict:
    # batch==1: shard the KV sequence over the data axis instead
    base = rules_multi_pod() if multi_pod else rules_single_pod()
    base = dict(base)
    base["batch"] = None
    base["seq"] = "data"
    return base
