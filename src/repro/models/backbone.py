"""Backbone assembly for all six architecture families.

Layers are *scan-stacked*: homogeneous blocks have their parameters stacked on
a leading layer axis and applied with jax.lax.scan, keeping HLO size (and 1-CPU
compile time) O(1) in depth.  Heterogeneous families scan their repeating
super-block pattern:

  dense              scan L blocks          (gemma2: scan L/2 (local, global) pairs)
  moe                scan L blocks with MoE FFN
  ssm (xlstm)        scan L/2 (mLSTM, sLSTM) pairs
  hybrid (zamba2)    scan L/k super-blocks of k mamba layers + ONE weight-shared
                     attention block applied after each super-block (Zamba trick)
  encdec (whisper)   scan encoder blocks (bidirectional), scan decoder blocks
                     (causal self-attn + cross-attn); conv/mel frontend stubbed —
                     the batch supplies frame embeddings
  vlm (internvl)     ViT stubbed — the batch supplies patch embeddings, which a
                     projector maps into the LM stream ahead of the tokens

Three entry points (built in models/steps.py into jit-able steps):
  forward(params, cfg, batch, kind)          -> logits  (train / prefill)
  init_decode_state(cfg, B, max_len)         -> cache pytree
  decode_step(params, cfg, state, tok, pos)  -> (logits, state)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    _init,
    init_rmsnorm,
    rmsnorm,
    init_attention,
    attention_apply,
    init_mlp,
    mlp_apply,
)
from .moe import init_moe, moe_apply
from . import ssm
from .sharding import constrain

COMPUTE_DTYPE = jnp.bfloat16


# --- per-family block init ---------------------------------------------------

def _init_dense_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _init_moe_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln2": init_rmsnorm(cfg.d_model),
        "moe": init_moe(k2, cfg),
    }


def _init_mamba_block(key, cfg):
    return {"ln1": init_rmsnorm(cfg.d_model), "mamba": ssm.init_mamba2(key, cfg)}


def _init_xlstm_pair(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln_m": init_rmsnorm(cfg.d_model),
        "mlstm": ssm.init_mlstm(k1, cfg),
        "ln_s": init_rmsnorm(cfg.d_model),
        "slstm": ssm.init_slstm(k2, cfg),
    }


def _init_encdec_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg),
        "ln_x": init_rmsnorm(cfg.d_model),
        "xattn": init_attention(k2, cfg),
        "ln2": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.activation),
    }


def _stacked(init_fn, key, n, cfg):
    return jax.vmap(lambda k: init_fn(k, cfg))(jax.random.split(key, n))


def init_model(key, cfg: ModelConfig):
    """Returns the fp32 parameter pytree.  Leaf names drive sharding."""
    keys = jax.random.split(key, 8)
    params = {"embedding": _init(keys[0], (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        params["unembed"] = _init(keys[1], (cfg.d_model, cfg.vocab_size), scale=0.02)
    params["ln_f"] = init_rmsnorm(cfg.d_model)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global_alternating:
            n_pairs = cfg.num_layers // 2
            params["layers"] = _stacked(
                lambda k, c: {
                    "local": _init_dense_block(jax.random.fold_in(k, 0), c),
                    "global": _init_dense_block(jax.random.fold_in(k, 1), c),
                },
                keys[2], n_pairs, cfg,
            )
        else:
            params["layers"] = _stacked(_init_dense_block, keys[2], cfg.num_layers, cfg)
        if fam == "vlm":
            params["patch_proj"] = _init(keys[3], (cfg.d_model, cfg.d_model))
    elif fam == "moe":
        params["layers"] = _stacked(_init_moe_block, keys[2], cfg.num_layers, cfg)
    elif fam == "ssm":
        params["layers"] = _stacked(_init_xlstm_pair, keys[2], cfg.num_layers // 2, cfg)
    elif fam == "hybrid":
        k_every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // k_every
        params["blocks"] = _stacked(
            lambda k, c: {"mamba_layers": _stacked(_init_mamba_block, k, k_every, c)},
            keys[2], n_super, cfg,
        )
        sk1, sk2 = jax.random.split(keys[3])
        params["shared_attn"] = {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": init_attention(sk1, cfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(sk2, cfg.d_model, cfg.d_ff, cfg.activation),
        }
    elif fam == "encdec":
        params["enc_layers"] = _stacked(_init_dense_block, keys[2], cfg.enc_layers, cfg)
        params["dec_layers"] = _stacked(_init_encdec_dec_block, keys[3], cfg.num_layers, cfg)
        params["ln_enc"] = init_rmsnorm(cfg.d_model)
        params["enc_pos_proj"] = _init(keys[4], (cfg.d_model, cfg.d_model))
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# --- block apply (train / prefill) --------------------------------------------

def _dense_block_apply(bp, x, cfg, positions, window, is_causal=True):
    h = attention_apply(
        bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, layer_window=window, is_causal=is_causal,
    )
    x = constrain(x + h, "batch", None, None)
    h = mlp_apply(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.activation)
    return constrain(x + h, "batch", None, None)


def _moe_block_apply(bp, x, cfg, positions):
    h = attention_apply(
        bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, layer_window=cfg.sliding_window,
    )
    x = x + h
    h, aux = moe_apply(bp["moe"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg)
    return constrain(x + h, "batch", None, None), aux


def _xlstm_pair_apply(bp, x, cfg):
    h, _ = ssm.mlstm_apply(bp["mlstm"], rmsnorm(bp["ln_m"], x, cfg.norm_eps), cfg)
    x = x + h
    h, _ = ssm.slstm_apply(bp["slstm"], rmsnorm(bp["ln_s"], x, cfg.norm_eps), cfg)
    return constrain(x + h, "batch", None, None)


def _mamba_block_apply(bp, x, cfg):
    h, _, _ = ssm.mamba2_apply(bp["mamba"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg)
    return constrain(x + h, "batch", None, None)


def _scan(fn, x, stacked, cfg, with_aux=False):
    from .sharding import gather_layer_params

    def gathered(lp, h):
        return fn(gather_layer_params(lp), h)

    L = jax.tree.leaves(stacked)[0].shape[0]
    groups = cfg.remat_blocks
    if cfg.remat and groups and L % groups == 0 and groups < L:
        # §Perf B1: two-level scan — checkpoint whole INNER groups so backward
        # stores only `groups` carries instead of L (inner layers recompute)
        inner = L // groups
        regrouped = jax.tree.map(lambda a: a.reshape(groups, inner, *a.shape[1:]), stacked)

        @jax.checkpoint
        def group_fn(grp, h):
            def body(carry, lp):
                if with_aux:
                    hh, aux = gathered(lp, carry)
                    return hh, aux
                return gathered(lp, carry), None
            return jax.lax.scan(body, h, grp)

        def outer(carry, grp):
            h, auxs = group_fn(grp, carry)
            return h, auxs

        x, auxs = jax.lax.scan(outer, x, regrouped)
        if with_aux:
            auxs = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), auxs)
        return (x, auxs) if with_aux else x

    wrapped = jax.checkpoint(gathered) if cfg.remat else gathered

    def body(carry, lp):
        if with_aux:
            h, aux = wrapped(lp, carry)
            return h, aux
        return wrapped(lp, carry), None

    x, auxs = jax.lax.scan(body, x, stacked)
    return (x, auxs) if with_aux else x


_KEEP_F32 = {"scale", "a_log", "dt_bias", "norm_scale", "bias"}


def cast_compute(params):
    """bf16 compute cast for matrix params; norm scales / ssm time-constants
    stay fp32 (matched by leaf name).  Master weights outside remain fp32."""

    def cast(path, a):
        name = getattr(path[-1], "key", getattr(path[-1], "name", "")) if path else ""
        if name in _KEEP_F32 or not hasattr(a, "dtype") or a.dtype != jnp.float32:
            return a
        return a.astype(COMPUTE_DTYPE)

    return jax.tree_util.tree_map_with_path(cast, params)


def forward(params, cfg: ModelConfig, batch: dict, kind: str = "train"):
    """-> (logits, aux).  batch: tokens (B,S) [+ enc_embed / patch_embed]."""
    params = cast_compute(params)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embedding"].astype(COMPUTE_DTYPE)[tokens]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(COMPUTE_DTYPE)
    x = constrain(x, "batch", None, None)
    aux = {}

    if cfg.family == "vlm":
        patches = batch["patch_embed"].astype(COMPUTE_DTYPE) @ params["patch_proj"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([patches, x], axis=1)
    S_eff = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_eff)[None], (B, S_eff))

    if cfg.family in ("dense", "vlm"):
        if cfg.local_global_alternating:
            def pair(bp, h):
                h = _dense_block_apply(bp["local"], h, cfg, positions, cfg.sliding_window)
                return _dense_block_apply(bp["global"], h, cfg, positions, None)
            x = _scan(pair, x, params["layers"], cfg)
        else:
            fn = lambda bp, h: _dense_block_apply(bp, h, cfg, positions, cfg.sliding_window)
            x = _scan(fn, x, params["layers"], cfg)
    elif cfg.family == "moe":
        fn = lambda bp, h: _moe_block_apply(bp, h, cfg, positions)
        x, auxs = _scan(fn, x, params["layers"], cfg, with_aux=True)
        aux = {k: jnp.mean(v) for k, v in auxs.items()}
    elif cfg.family == "ssm":
        x = _scan(lambda bp, h: _xlstm_pair_apply(bp, h, cfg), x, params["layers"], cfg)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def superblock(bp, h):
            h = _scan(lambda mp, hh: _mamba_block_apply(mp, hh, cfg), h, bp["mamba_layers"], cfg)
            return _dense_block_apply(shared, h, cfg, positions, cfg.sliding_window)

        x = _scan(superblock, x, params["blocks"], cfg)
    elif cfg.family == "encdec":
        enc = batch["enc_embed"].astype(COMPUTE_DTYPE) @ params["enc_pos_proj"].astype(COMPUTE_DTYPE)
        enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None], (B, enc.shape[1]))
        enc_fn = lambda bp, h: _dense_block_apply(bp, h, cfg, enc_pos, None, is_causal=False)
        enc = _scan(enc_fn, enc, params["enc_layers"], cfg)
        enc = rmsnorm(params["ln_enc"], enc, cfg.norm_eps)

        def dec_block(bp, h):
            a = attention_apply(bp["attn"], rmsnorm(bp["ln1"], h, cfg.norm_eps), cfg,
                                positions=positions)
            h = h + a
            a = attention_apply(bp["xattn"], rmsnorm(bp["ln_x"], h, cfg.norm_eps), cfg,
                                positions=positions, is_causal=False, x_kv=enc)
            h = h + a
            a = mlp_apply(bp["mlp"], rmsnorm(bp["ln2"], h, cfg.norm_eps), cfg.activation)
            return constrain(h + a, "batch", None, None)

        x = _scan(dec_block, x, params["dec_layers"], cfg)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.family == "vlm":  # logits over the token positions only
        x = x[:, -S:]
    unembed = (
        params["embedding"].astype(COMPUTE_DTYPE).T
        if cfg.tie_embeddings
        else params["unembed"].astype(COMPUTE_DTYPE)
    )
    logits = x @ unembed
    if cfg.final_logit_softcap is not None:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_logit_softcap
        ).astype(logits.dtype)
    logits = constrain(logits, "batch", None, "tensor")
    return logits, aux
