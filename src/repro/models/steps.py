"""Jit-able step functions: train_step / prefill_step / decode_step builders.

These are what the launcher lowers in the multi-pod dry-run and what the
training driver runs.  Loss is next-token cross-entropy computed in fp32 with
the logsumexp trick (no fp32 logits materialization beyond one (B,S,V) temp).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .backbone import forward, init_model
from .decode import decode_step as _decode_step, init_decode_state
from ..optim import AdamWState, adamw_init, adamw_update, cosine_warmup
from ..compat import shard_map, get_abstract_mesh

MOE_AUX_WEIGHT = 0.01
ROUTER_Z_WEIGHT = 1e-3


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch, kind="train")
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = nll
    if aux:
        total = total + MOE_AUX_WEIGHT * aux.get("load_balance", 0.0)
        total = total + ROUTER_Z_WEIGHT * aux.get("router_z", 0.0)
    metrics = {"loss": nll, **{f"moe/{k}": v for k, v in aux.items()}}
    return total, metrics


def make_train_step(
    cfg: ModelConfig,
    *,
    peak_lr=3e-4,
    warmup=100,
    total_steps=10000,
    microbatches: int = 1,
    qcomm_bits: int = 0,
    pod_axis: str = "pod",
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` runs gradient accumulation: the global batch is split
    on the leading axis and scanned, so live activations (layer-scan carries,
    logits) scale with the microbatch, not the global batch — the difference
    between fitting and not fitting HBM for the large train_4k configs.

    ``qcomm_bits > 0`` applies the PAPER'S quantization scheme to the
    cross-pod gradient reduction (§Perf C): the per-pod gradient is computed
    under a shard_map that is manual over the pod axis only, and the pod-axis
    all-reduce is replaced by repro.comm.q_psum — int codes on the (slow,
    DCN-class) inter-pod links instead of fp32."""

    def grad_fn(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)

    def accumulate_grads(params, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = jax.tree.map(
            lambda a: a.reshape(microbatches, B // microbatches, *a.shape[1:]), batch
        )
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mbatch):
            g_acc, _ = carry
            (_, metrics), g = grad_fn(params, mbatch)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, metrics), None

        (grads, metrics), _ = jax.lax.scan(acc, (zero_g, _zero_metrics(cfg)), mb)
        return jax.tree.map(lambda g: g / microbatches, grads), metrics

    def train_step(params, opt_state: AdamWState, batch):
        if qcomm_bits:
            from jax.sharding import PartitionSpec as P
            from ..comm import q_psum
            from .sharding import tree_param_specs

            mesh = get_abstract_mesh()
            n_pods = dict(mesh.shape).get(pod_axis, 1)

            # stage 1: per-pod gradients (manual over the pod axis only; NO
            # pod-axis collectives inside — XLA's partitioner cannot lower
            # them under partial-manual mode).  Each pod's grads come out
            # stacked on a new leading pod dim.
            from .sharding import logical_rules, current_rules, tree_param_specs as _tps

            def _strip(ax, rules):
                out = {}
                for k, v in rules.items():
                    if isinstance(v, tuple):
                        v = tuple(a for a in v if a != ax) or None
                        v = v[0] if isinstance(v, tuple) and len(v) == 1 else v
                    elif v == ax:
                        v = None
                    out[k] = v
                return out

            inner_rules = _strip(pod_axis, current_rules())

            # stage 1: per-pod gradients WITHOUT manual mode (XLA's partial-
            # manual partitioner crashes on embedding gather/scatter —
            # b/433785288).  Parameters are stacked on a pod-sharded leading
            # dim and the model is vmapped over it: lane i sees pod i's batch
            # shard only, so autodiff cannot insert a cross-pod all-reduce.
            pspecs0 = _tps(params, mesh)
            params_p = jax.tree.map(
                lambda a, sp: jax.lax.with_sharding_constraint(
                    jnp.broadcast_to(a[None], (n_pods,) + a.shape),
                    P(pod_axis, *tuple(sp)),
                ),
                params, pspecs0,
            )
            batch_p = jax.tree.map(
                lambda a: jax.lax.with_sharding_constraint(
                    a.reshape((n_pods, a.shape[0] // n_pods) + a.shape[1:]),
                    P(pod_axis, inner_rules.get("batch")),
                ),
                batch,
            )

            def per_pod(params_l, batch_l):
                with logical_rules(inner_rules):
                    return accumulate_grads(params_l, batch_l)

            grads_p, metrics_p = jax.vmap(per_pod)(params_p, batch_p)

            # stage 2: the paper's quantized all-reduce over the pod axis,
            # FULL-manual (per-leaf layouts from the param sharding rules)
            pspecs = tree_param_specs(params, mesh)

            def prepend(spec):
                return P(pod_axis, *tuple(spec))

            def reduce_leaf(g_l):
                return q_psum(g_l[0], pod_axis, qcomm_bits) / n_pods

            grads = jax.tree.map(
                lambda g, sp: shard_map(
                    reduce_leaf,
                    mesh=mesh,
                    in_specs=prepend(sp),
                    out_specs=sp,
                    check_vma=False,
                )(g),
                grads_p, pspecs,
            )
            metrics = jax.tree.map(lambda t: jnp.mean(t, axis=0), metrics_p)
        else:
            grads, metrics = accumulate_grads(params, batch)
        lr = cosine_warmup(opt_state.step, peak_lr=peak_lr, warmup_steps=warmup, total_steps=total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr)
        metrics = {**metrics, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def _zero_metrics(cfg: ModelConfig):
    m = {"loss": jnp.zeros((), jnp.float32)}
    if cfg.family == "moe":
        m.update({
            "moe/load_balance": jnp.zeros((), jnp.float32),
            "moe/router_z": jnp.zeros((), jnp.float32),
            "moe/drop_frac": jnp.zeros((), jnp.float32),
        })
    return m


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits (B, V): the inference prefill."""

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch, kind="prefill")
        return logits[:, -1]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, state, tokens (B,1), pos) -> (next_tokens (B,1), state)."""

    def step(params, state, tokens, pos):
        logits, state = _decode_step(params, cfg, state, tokens, pos)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt[:, None].astype(jnp.int32), state

    return step


def init_train_state(key, cfg: ModelConfig):
    params = init_model(key, cfg)
    return params, adamw_init(params)
