"""Shared neural building blocks: norms, RoPE, attention (full / sliding /
chunked-online-softmax / decode-with-cache), gated MLPs.

Functional style: ``init_*`` build param dicts (leaf names drive sharding,
see models/sharding.py); ``*_apply`` are pure.
Compute dtype is bf16, accumulation fp32, params passed in as given.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .sharding import constrain

ATTN_CHUNK = 1024  # KV chunk for memory-efficient attention
ATTN_DENSE_MAX = 8192  # use plain dense attention up to this seq len


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(shape[0] if len(shape) > 1 else 1.0)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# --- norms ------------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# --- rotary embeddings --------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- attention ---------------------------------------------------------------

def init_attention(key, cfg):
    ks = jax.random.split(key, 4)
    D, Hq, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return {
        "wq": _init(ks[0], (D, Hq * hd)),
        "wk": _init(ks[1], (D, Hkv * hd)),
        "wv": _init(ks[2], (D, Hkv * hd)),
        "wo": _init(ks[3], (Hq * hd, D)),
    }


def _softcap(x, cap: Optional[float]):
    return x if cap is None else cap * jnp.tanh(x / cap)


def _group_q(q, n_kv):
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def _attn_dense(q, k, v, mask, softcap):
    """q: (B,Sq,KV,G,hd) k/v: (B,Sk,KV,hd); mask (B,1,1,Sq,Sk) or broadcastable."""
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    scores = _softcap(scores, softcap)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v)


def _attn_chunked(q, k, v, qpos, kpos, window, softcap, is_causal):
    """Online-softmax attention, scanning KV in chunks (memory ~ O(Sq*chunk)).

    q: (B,Sq,KV,G,hd); k/v: (B,Sk,KV,hd); qpos (B,Sq); kpos (B,Sk)."""
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    C = min(ATTN_CHUNK, Sk)
    pad = (-Sk) % C
    if pad:  # pad KV to a chunk multiple; padded keys masked via kpos = -1
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
        Sk += pad
    n_chunks = Sk // C
    qf = q.astype(jnp.float32)

    def body(carry, inputs):
        acc, m, denom = carry
        kc, vc, pc = inputs  # (B,C,KV,hd), (B,C,KV,hd), (B,C)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kc.astype(jnp.float32))
        s = _softcap(s, softcap)
        valid = (pc >= 0)[:, None, None, None, :]  # padded keys are kpos == -1
        if is_causal:
            valid &= (qpos[:, None, None, :, None] >= pc[:, None, None, None, :])
        if window is not None:
            valid &= (qpos[:, None, None, :, None] - pc[:, None, None, None, :]) < window
        s = jnp.where(valid, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, denom), None

    ks = k.reshape(B, n_chunks, C, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, C, KV, hd).transpose(1, 0, 2, 3, 4)
    ps = kpos.reshape(B, n_chunks, C).transpose(1, 0, 2)
    acc0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), -jnp.inf)
    d0 = jnp.zeros((B, KV, G, Sq))
    (acc, m, denom), _ = jax.lax.scan(body, (acc0, m0, d0), (ks, vs, ps))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,KV,G,hd)


def attention_apply(
    params,
    x,
    cfg,
    *,
    positions,
    layer_window: Optional[int] = None,
    is_causal: bool = True,
    kv_cache=None,
    cache_len=None,
    x_kv=None,
):
    """General attention.

    * self-attention train/prefill: x (B,S,D), kv_cache None
    * cross-attention: x_kv (B,Sk,D) supplies K/V (no mask)
    * decode: kv_cache=(K,V) (B,Smax,KV,hd), cache_len scalar — x is (B,1,D);
      returns (out, new_cache)
    """
    B, S, D = x.shape
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    src = x if x_kv is None else x_kv
    q = (x @ params["wq"]).reshape(B, S, Hq, hd)
    k = (src @ params["wk"]).reshape(B, src.shape[1], Hkv, hd)
    v = (src @ params["wv"]).reshape(B, src.shape[1], Hkv, hd)

    if x_kv is None:  # rope only for self-attention
        q = rope(q, positions, cfg.rope_theta)
        if kv_cache is None:
            k = rope(k, positions, cfg.rope_theta)
        else:
            k = rope(k, positions[:, -1:], cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        K, V = kv_cache
        K = jax.lax.dynamic_update_slice_in_dim(K, k.astype(K.dtype), cache_len, axis=1)
        V = jax.lax.dynamic_update_slice_in_dim(V, v.astype(V.dtype), cache_len, axis=1)
        new_cache = (K, V)
        kpos = jnp.broadcast_to(jnp.arange(K.shape[1])[None], (B, K.shape[1]))
        qg = _group_q(q, Hkv)
        mask = kpos[:, None, None, None, :] <= cache_len
        if layer_window is not None:
            mask &= kpos[:, None, None, None, :] > (cache_len - layer_window)
        out = _attn_dense(qg, K, V, mask, cfg.attn_logit_softcap)
    else:
        qg = _group_q(q, Hkv)
        Sk = k.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
        if S * Sk > ATTN_DENSE_MAX * ATTN_DENSE_MAX:
            out = _attn_chunked(qg, k, v, positions, kpos, layer_window,
                                cfg.attn_logit_softcap, is_causal)
        else:
            mask = jnp.ones((B, 1, 1, S, Sk), bool)
            if is_causal:
                mask &= positions[:, None, None, :, None] >= kpos[:, None, None, None, :]
            if layer_window is not None:
                mask &= (positions[:, None, None, :, None] - kpos[:, None, None, None, :]) < layer_window
            out = _attn_dense(qg, k, v, mask, cfg.attn_logit_softcap)

    out = out.reshape(B, S, Hq * hd).astype(x.dtype)
    out = constrain(out, "batch", None, "tensor")
    proj = out @ params["wo"]
    return (proj, new_cache) if kv_cache is not None else proj


# --- MLP ---------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, activation):
    k1, k2 = jax.random.split(key)
    width = 2 * d_ff if activation in ("swiglu", "geglu") else d_ff
    return {"wi": _init(k1, (d_model, width)), "wo_mlp": _init(k2, (d_ff, d_model))}


def mlp_apply(params, x, activation):
    h = x @ params["wi"]
    h = constrain(h, "batch", None, "tensor")
    if activation in ("swiglu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(h)
    return h @ params["wo_mlp"]
