"""Minimal sharded checkpointing: pytree <-> npz with /-joined key paths.

Restore is sharding-aware: pass ``shardings`` (a matching pytree of
NamedShardings or None) and leaves are device_put into place.

Artifact checkpoints (:func:`save_artifact` / :func:`load_artifact_arrays`)
pair the npz with a sidecar json of static metadata, so a registered-dataclass
pytree like ``core.distributed_gp.FittedProtocol`` can be restored WITHOUT the
original object as a template (the caller rebuilds from metadata + key paths).

Array leaves are saved exactly as they flatten — including the streaming
capacity padding of a format-v5 artifact (docs/wire_format.md): the
``stream/*`` int32 leaves (per-machine counts, the occupied-column counter,
the three wire ledgers) ride along as ordinary pytree keys, and the padded
buffers restore at their saved capacity so a reloaded artifact streams on in
the same bucket, bitwise.
"""
from __future__ import annotations

import json
import os
import re
import zlib

import numpy as np
import jax


class CorruptCheckpointError(ValueError):
    """An artifact array failed its recorded CRC32 checksum on load (the
    message names the bad array).  Raised instead of serving from silently
    corrupted factors — catch it to fall back to an older step."""


def _key_str(k):
    # DictKey has .key, GetAttrKey (registered dataclasses) has .name,
    # SequenceKey (tuples/namedtuples) has .idx
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        # device_get first: leaves sharded across a mesh (e.g. a
        # FittedProtocol fit with impl="mesh") gather to one host array, so
        # every checkpoint is a single-host artifact
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez keeps the name when it ends in .npz
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (values ignored)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (pathk, leaf), sh in zip(flat, shard_flat):
        key = "/".join(_key_str(k) for k in pathk)
        arr = data[key]
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def array_checksum(arr) -> int:
    """CRC32 of an array's raw bytes (C-contiguous) — the per-array integrity
    record written into artifact ``meta.json`` (format v4)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_artifact(directory: str, step: int, tree, meta: dict) -> str:
    """Checkpoint a pytree PLUS a json of static metadata, atomically.

    The npz carries the array leaves (same key-path layout as
    :func:`save_checkpoint`); ``meta`` must be json-serializable and carry
    whatever the caller needs to rebuild the object without a template
    (:func:`load_artifact_arrays` hands both back).  A per-array CRC32
    checksum table is recorded under ``meta["array_checksums"]`` (format v4)
    so a bit-rotted npz fails loud at load instead of serving garbage."""
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez keeps the name when it ends in .npz
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    meta = dict(meta)
    meta["array_checksums"] = {k: array_checksum(v) for k, v in flat.items()}
    meta_path = os.path.join(directory, f"meta_{step:08d}.json")
    tmpm = meta_path + ".tmp"
    with open(tmpm, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmpm, meta_path)
    return path


def load_artifact_meta(directory: str, step: int | None = None) -> dict:
    """The sidecar metadata of an artifact checkpoint WITHOUT touching the
    npz — a cheap screen (protocol, config, format version) before paying an
    array load.  The fleet's artifact store uses this to check
    bucket-compatibility of a tenant before admitting it.  ``step=None``
    loads the latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    with open(os.path.join(directory, f"meta_{step:08d}.json")) as f:
        return json.load(f)


def load_artifact_arrays(directory: str, step: int | None = None):
    """(meta, {key_path: np.ndarray}) for an artifact checkpoint; ``step=None``
    loads the latest.  When the meta records ``array_checksums`` (format v4),
    every array is verified against its CRC32 and a mismatch raises
    :class:`CorruptCheckpointError` naming the bad array; older checkpoints
    (v1-v3, no checksum table) load unverified."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    with open(os.path.join(directory, f"meta_{step:08d}.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    arrays = {k: data[k] for k in data.files}
    checksums = meta.get("array_checksums")
    if checksums:
        for k, want in checksums.items():
            if k not in arrays:
                raise CorruptCheckpointError(
                    f"artifact checkpoint step {step} is missing array {k!r} "
                    f"recorded in meta_{step:08d}.json"
                )
            got = array_checksum(arrays[k])
            if got != int(want):
                raise CorruptCheckpointError(
                    f"artifact array {k!r} failed its checksum at step {step}: "
                    f"crc32 {got:#010x} != recorded {int(want):#010x} "
                    f"(ckpt_{step:08d}.npz is corrupted)"
                )
    return meta, arrays
