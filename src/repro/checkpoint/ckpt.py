"""Minimal sharded checkpointing: pytree <-> npz with /-joined key paths.

Restore is sharding-aware: pass ``shardings`` (a matching pytree of
NamedShardings or None) and leaves are device_put into place.
"""
from __future__ import annotations

import os
import re

import numpy as np
import jax


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez keeps the name when it ends in .npz
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (values ignored)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (pathk, leaf), sh in zip(flat, shard_flat):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in pathk)
        arr = data[key]
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
