"""Minimal sharded checkpointing: pytree <-> npz with /-joined key paths.

Restore is sharding-aware: pass ``shardings`` (a matching pytree of
NamedShardings or None) and leaves are device_put into place.

Artifact checkpoints (:func:`save_artifact` / :func:`load_artifact_arrays`)
pair the npz with a sidecar json of static metadata, so a registered-dataclass
pytree like ``core.distributed_gp.FittedProtocol`` can be restored WITHOUT the
original object as a template (the caller rebuilds from metadata + key paths).
"""
from __future__ import annotations

import json
import os
import re

import numpy as np
import jax


def _key_str(k):
    # DictKey has .key, GetAttrKey (registered dataclasses) has .name,
    # SequenceKey (tuples/namedtuples) has .idx
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        # device_get first: leaves sharded across a mesh (e.g. a
        # FittedProtocol fit with impl="mesh") gather to one host array, so
        # every checkpoint is a single-host artifact
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez keeps the name when it ends in .npz
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (values ignored)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (pathk, leaf), sh in zip(flat, shard_flat):
        key = "/".join(_key_str(k) for k in pathk)
        arr = data[key]
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_artifact(directory: str, step: int, tree, meta: dict) -> str:
    """Checkpoint a pytree PLUS a json of static metadata, atomically.

    The npz carries the array leaves (same key-path layout as
    :func:`save_checkpoint`); ``meta`` must be json-serializable and carry
    whatever the caller needs to rebuild the object without a template
    (:func:`load_artifact_arrays` hands both back)."""
    path = save_checkpoint(directory, step, tree)
    meta_path = os.path.join(directory, f"meta_{step:08d}.json")
    tmp = meta_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmp, meta_path)
    return path


def load_artifact_arrays(directory: str, step: int | None = None):
    """(meta, {key_path: np.ndarray}) for an artifact checkpoint; ``step=None``
    loads the latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    with open(os.path.join(directory, f"meta_{step:08d}.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    return meta, {k: data[k] for k in data.files}
