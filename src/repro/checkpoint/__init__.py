from .ckpt import (
    CorruptCheckpointError,
    array_checksum,
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    save_artifact,
    load_artifact_arrays,
    load_artifact_meta,
)
