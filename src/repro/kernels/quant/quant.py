"""Pallas TPU kernels for the paper's per-symbol quantizer (§4.2).

encode: code[i, j] = #( scaled_edges[j, :] < x[i, j] )   — bin search as a
        vectorized threshold-count (VPU-friendly; no gathers on TPU).
decode: xhat[i, j] = centroids[j, code[i, j]]            — gather expressed as
        a one-hot contraction, chunked so the (bn, bd, bC) temp fits VMEM.

Per-dimension rates are baked into the (d, E)/(d, C) tables by padding: unused
edges are +inf (never counted), unused centroids are 0 (never selected since
codes < 2^rate).  Grid: (n/bn, d/bd); the edge/centroid axis is looped inside
the kernel in chunks of ``echunk`` to bound the 3-D temporary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (128, 128)  # (bn, bd)
DEFAULT_ECHUNK = 128


def _encode_kernel(x_ref, edges_ref, o_ref, *, echunk: int):
    x = x_ref[...]  # (bn, bd)
    n_chunks = edges_ref.shape[1] // echunk

    def body(c, acc):
        e = edges_ref[:, pl.dslice(c * echunk, echunk)]  # (bd, echunk)
        # (bn, bd, echunk) threshold count
        return acc + jnp.sum(x[:, :, None] > e[None, :, :], axis=-1, dtype=jnp.int32)

    o_ref[...] = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(x.shape, dtype=jnp.int32)
    )


def _decode_kernel(codes_ref, cents_ref, o_ref, *, echunk: int):
    codes = codes_ref[...]  # (bn, bd) int32
    n_chunks = cents_ref.shape[1] // echunk

    def body(c, acc):
        cents = cents_ref[:, pl.dslice(c * echunk, echunk)]  # (bd, echunk)
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, echunk), 2) + c * echunk
        onehot = (codes[:, :, None] == idx).astype(cents.dtype)
        return acc + jnp.sum(onehot * cents[None, :, :], axis=-1)

    o_ref[...] = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(codes.shape, dtype=cents_ref.dtype)
    )


@functools.partial(jax.jit, static_argnames=("block", "echunk", "interpret"))
def encode_pallas(x, scaled_edges, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=False):
    """x: (n, d); scaled_edges: (d, E) with E % echunk == 0 -> int32 codes (n, d)."""
    n, d = x.shape
    bn, bd = block
    grid = (n // bn, d // bd)
    return pl.pallas_call(
        functools.partial(_encode_kernel, echunk=echunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, scaled_edges.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.int32),
        interpret=interpret,
    )(x, scaled_edges)


@functools.partial(jax.jit, static_argnames=("block", "echunk", "interpret"))
def decode_pallas(codes, scaled_cents, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=False):
    """codes: (n, d) int32; scaled_cents: (d, C), C % echunk == 0 -> (n, d) fp32."""
    n, d = codes.shape
    bn, bd = block
    grid = (n // bn, d // bd)
    return pl.pallas_call(
        functools.partial(_decode_kernel, echunk=echunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd, scaled_cents.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(codes, scaled_cents)
