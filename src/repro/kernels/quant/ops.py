"""Public wrappers for the quantizer kernels.

Builds the per-dimension scaled tables from (sigma, rates) using
repro.core.quantizers codebooks, pads everything to tile multiples, and runs
the Pallas kernels.  Backend selection (compiled Pallas on TPU, jitted-XLA
fallback elsewhere, ``REPRO_FORCE_PALLAS=1`` for interpret-mode debugging) is
the unified runtime policy — :func:`repro.kernels.runtime.choose`.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ...core import quantizers as Q
from .. import runtime
from .quant import encode_pallas, decode_pallas, DEFAULT_BLOCK, DEFAULT_ECHUNK
from .ref import encode_ref, decode_ref


_encode_xla = jax.jit(encode_ref)
_decode_xla = jax.jit(decode_ref)


def _pad_axis(a, mult, axis, value=0.0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def build_scaled_tables(sigma, rates, echunk: int = DEFAULT_ECHUNK):
    """(d,) sigma, (d,) int rates -> scaled_edges (d, E), scaled_cents (d, C)
    with E/C padded to ``echunk`` multiples; unused edges +inf, cents 0."""
    rates = np.asarray(rates, dtype=np.int64)
    sigma = np.asarray(sigma, dtype=np.float32)
    d = rates.shape[0]
    max_r = int(rates.max(initial=0))
    E = max(1 << max_r, echunk) if max_r > 0 else echunk
    E = int(np.ceil(E / echunk) * echunk)
    edges = np.full((d, E), np.inf, dtype=np.float32)
    cents = np.zeros((d, E), dtype=np.float32)
    for i in range(d):
        r = int(rates[i])
        e = Q.gauss_bin_edges(r)
        c = Q.gauss_centroids(r)
        edges[i, : e.shape[0]] = e * sigma[i]
        cents[i, : c.shape[0]] = c * sigma[i]
    return jnp.asarray(edges), jnp.asarray(cents)


def _encode_kernel_path(x, scaled_edges, *, interpret: bool,
                        block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK):
    n, d = x.shape
    bn, bd = block
    xp = _pad_axis(_pad_axis(jnp.asarray(x, jnp.float32), bn, 0), bd, 1)
    ep = _pad_axis(jnp.asarray(scaled_edges), bd, 0, value=np.inf)
    out = encode_pallas(xp, ep, block=block, echunk=echunk, interpret=interpret)
    return out[:n, :d]


def _decode_kernel_path(codes, scaled_cents, *, interpret: bool,
                        block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK):
    n, d = codes.shape
    bn, bd = block
    cp = _pad_axis(_pad_axis(jnp.asarray(codes), bn, 0), bd, 1)
    tp = _pad_axis(jnp.asarray(scaled_cents), bd, 0)
    out = decode_pallas(cp, tp, block=block, echunk=echunk, interpret=interpret)
    return out[:n, :d]


runtime.register_kernel_op(runtime.KernelImpl(
    name="quant_encode",
    pallas=_encode_kernel_path,
    xla=lambda x, e, block=None, echunk=None: _encode_xla(
        jnp.asarray(x, jnp.float32), jnp.asarray(e)
    ),
    ref=encode_ref,
))
runtime.register_kernel_op(runtime.KernelImpl(
    name="quant_decode",
    pallas=_decode_kernel_path,
    xla=lambda c, t, block=None, echunk=None: _decode_xla(
        jnp.asarray(c), jnp.asarray(t)
    ),
    ref=decode_ref,
))


def encode(x, scaled_edges, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=None):
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _encode_xla(jnp.asarray(x, jnp.float32), jnp.asarray(scaled_edges))
    return _encode_kernel_path(
        x, scaled_edges, interpret=d.interpret, block=block, echunk=echunk
    )


def decode(codes, scaled_cents, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=None):
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _decode_xla(jnp.asarray(codes), jnp.asarray(scaled_cents))
    return _decode_kernel_path(
        codes, scaled_cents, interpret=d.interpret, block=block, echunk=echunk
    )
