"""Pure-jnp oracle for the per-symbol quantizer kernels."""
import jax.numpy as jnp


def encode_ref(x, scaled_edges):
    """code = #(edges below x); +inf padding rows never count."""
    return jnp.sum(
        jnp.asarray(x)[:, :, None] > scaled_edges[None, :, :], axis=-1
    ).astype(jnp.int32)


def decode_ref(codes, scaled_cents):
    """xhat[i, j] = cents[j, codes[i, j]]."""
    d = scaled_cents.shape[0]
    return scaled_cents[jnp.arange(d), codes]
