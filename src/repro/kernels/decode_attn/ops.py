"""Public wrapper for the decode-attention kernel: pads S to a chunk multiple
(padded slots get kpos = -1, masked inside), normalizes acc/denom.  Backend
selection is the unified runtime policy (:func:`repro.kernels.runtime
.choose`) — this family used to run interpret-mode Pallas unconditionally
off-TPU; it now gets the same jitted-XLA fallback as the others."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import runtime
from .decode_attn import decode_attn_pallas, DEFAULT_CHUNK
from .ref import decode_attn_ref


_decode_attn_xla = functools.partial(jax.jit, static_argnames=("window",))(
    lambda q, K, V, kpos, pos, window=None: decode_attn_ref(
        q, K, V, kpos, pos, window=window
    )
)


def _decode_attn_kernel_path(q, K, V, kpos, pos, *, interpret: bool,
                             window=None, chunk=DEFAULT_CHUNK):
    B, S = K.shape[:2]
    C = min(chunk, max(S, 1))
    pad = (-S) % C
    if pad:
        K = jnp.pad(K, ((0, 0), (0, pad), (0, 0), (0, 0)))
        V = jnp.pad(V, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    acc, m, d = decode_attn_pallas(
        q, K, V, kpos.astype(jnp.int32),
        jnp.asarray([pos], jnp.int32),
        chunk=C, window=window, interpret=interpret,
    )
    return acc / jnp.maximum(d[..., None], 1e-30)


runtime.register_kernel_op(runtime.KernelImpl(
    name="decode_attn",
    pallas=_decode_attn_kernel_path,
    xla=lambda q, K, V, kpos, pos, window=None, chunk=None: _decode_attn_xla(
        q, K, V, kpos, pos, window=window
    ),
    ref=decode_attn_ref,
))


def decode_attn(q, K, V, kpos, pos, *, window=None, chunk=DEFAULT_CHUNK, interpret=None):
    """q: (B,KV,G,hd); K/V: (B,S,KV,hd); kpos: (B,S) int32 (-1 = empty slot);
    pos: scalar int32.  Returns (B,KV,G,hd) fp32."""
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _decode_attn_xla(q, K, V, kpos, pos, window=window)
    return _decode_attn_kernel_path(
        q, K, V, kpos, pos, interpret=d.interpret, window=window, chunk=chunk
    )
