"""Public wrapper for the decode-attention kernel: pads S to a chunk multiple
(padded slots get kpos = -1, masked inside), normalizes acc/denom."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attn import decode_attn_pallas, DEFAULT_CHUNK


def decode_attn(q, K, V, kpos, pos, *, window=None, chunk=DEFAULT_CHUNK, interpret=None):
    """q: (B,KV,G,hd); K/V: (B,S,KV,hd); kpos: (B,S) int32 (-1 = empty slot);
    pos: scalar int32.  Returns (B,KV,G,hd) fp32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S = K.shape[:2]
    C = min(chunk, max(S, 1))
    pad = (-S) % C
    if pad:
        K = jnp.pad(K, ((0, 0), (0, pad), (0, 0), (0, 0)))
        V = jnp.pad(V, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    acc, m, d = decode_attn_pallas(
        q, K, V, kpos.astype(jnp.int32),
        jnp.asarray([pos], jnp.int32),
        chunk=C, window=window, interpret=interpret,
    )
    return acc / jnp.maximum(d[..., None], 1e-30)
