"""Pallas TPU kernel: single-token GQA decode attention over a (ring) KV cache.

The hot loop of decode_32k / long_500k: one query head-group against S cached
keys, with position-validity masking (ring caches store kpos; invalid slots
are kpos == -1) and an optional sliding window.

Streaming formulation: grid (B, S/chunk); each step loads a (chunk, KV, hd)
K/V tile into VMEM and updates unnormalized online-softmax accumulators that
live in the (revisited) output tiles:

    m'   = max(m, max_s s_i)          (running max,   (KV, G))
    acc' = acc * e^{m-m'} + e^{s-m'}V (unnormalized,  (KV, G, hd))
    d'   = d * e^{m-m'} + sum e^{s-m'}  (denominator, (KV, G))

The wrapper divides acc/d outside (one cheap elementwise).  This keeps the
kernel output-accumulator-only (no scratch), the same pattern as the gram
kernel, and O(chunk) VMEM per step regardless of S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 512
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, kpos_ref, pos_ref, acc_ref, m_ref, d_ref, *, window):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0]  # (KV, G, hd)
    k = k_ref[0]  # (C, KV, hd)
    v = v_ref[0]  # (C, KV, hd)
    kpos = kpos_ref[0]  # (C,)
    pos = pos_ref[0]

    s = jnp.einsum("kgh,ckh->kgc", q.astype(jnp.float32), k.astype(jnp.float32))
    valid = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, None, :], s, NEG)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)  # (KV, G)
    p = jnp.exp(s - m_new[..., None])  # (KV, G, C)
    acc_ref[0] = acc_ref[0] * alpha[..., None] + jnp.einsum(
        "kgc,ckh->kgh", p, v.astype(jnp.float32)
    )
    d_ref[0] = d_ref[0] * alpha + jnp.sum(p, axis=-1)
    m_ref[0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "window", "interpret"))
def decode_attn_pallas(q, K, V, kpos, pos, *, chunk=DEFAULT_CHUNK, window=None, interpret=False):
    """q: (B, KV, G, hd); K/V: (B, S, KV, hd); kpos: (B, S) int32; pos: (1,)
    int32.  S % chunk == 0 (ops.py pads).  Returns unnormalized
    (acc (B,KV,G,hd) fp32, m (B,KV,G), denom (B,KV,G))."""
    B, KV, G, hd = q.shape
    S = K.shape[1]
    grid = (B, S // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, chunk, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, chunk, KV, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, chunk), lambda b, s: (b, s)),
            pl.BlockSpec((1,), lambda b, s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, KV, G), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, KV, G), lambda b, s: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
        ],
        interpret=interpret,
    )(q, K, V, kpos, pos)
