# subpackage marker (kernel impl + ops wrapper + ref oracle; see kernels/__init__.py)
