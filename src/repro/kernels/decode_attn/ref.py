"""Pure-jnp oracle for the decode-attention kernel."""
import jax.numpy as jnp


def decode_attn_ref(q, K, V, kpos, pos, window=None):
    """q: (B,KV,G,hd); K/V: (B,S,KV,hd); kpos: (B,S); pos scalar.
    Returns (B,KV,G,hd) normalized attention output (fp32)."""
    s = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32), K.astype(jnp.float32))
    valid = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        valid &= kpos > pos - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", w, V.astype(jnp.float32))
