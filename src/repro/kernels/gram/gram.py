"""Pallas TPU kernel: tiled gram-block computation  G = X @ Y^T.

This is the hot loop of the paper's distributed GP: every cross-machine block
G_ij of the gram matrix is an inner-product matrix between (reconstructed)
datasets.  Tiling: grid (n/bn, p/bp, d/bd); X and Y stream HBM->VMEM in
(bn, bd)/(bp, bd) tiles; the (bn, bp) fp32 accumulator tile lives in VMEM
across the k-steps (revisited output), hitting the MXU with 128-aligned dots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (128, 128, 128)  # (bn, bp, bd) — MXU-aligned


def _gram_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # X @ Y^T
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gram_pallas(x, y, *, block=DEFAULT_BLOCK, interpret=False):
    """x: (n, d), y: (p, d) -> (n, p) fp32.  Shapes must be block-multiples
    (ops.py pads)."""
    n, d = x.shape
    p, _ = y.shape
    bn, bp, bd = block
    grid = (n // bn, p // bp, d // bd)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(x, y)
