"""Jit'd public wrapper for the gram kernel: pads to block multiples, selects
interpret mode off-TPU, unpads the result."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gram import gram_pallas, DEFAULT_BLOCK


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def gram(x, y, *, block=DEFAULT_BLOCK, interpret: bool | None = None):
    """G = X @ Y^T via the Pallas kernel, any (n, d)/(p, d) shapes."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, p = x.shape[0], y.shape[0]
    bn, bp, bd = block
    xp = _pad_to(_pad_to(jnp.asarray(x, jnp.float32), bn, 0), bd, 1)
    yp = _pad_to(_pad_to(jnp.asarray(y, jnp.float32), bp, 0), bd, 1)
    out = gram_pallas(xp, yp, block=block, interpret=interpret)
    return out[:n, :p]
