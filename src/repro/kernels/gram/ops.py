"""Jit'd public wrapper for the gram kernel: pads to block multiples, routes
backend selection through the unified kernel runtime, unpads the result.

``gram`` carries a custom VJP (dX = g @ Y, dY = g^T @ X — both themselves gram
products, routed back through the kernel), so kernels that consume it stay
differentiable end-to-end when hyperparameter training runs with
``gram_backend="pallas"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import runtime
from .gram import gram_pallas, DEFAULT_BLOCK
from .ref import gram_ref


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


_gram_xla = jax.jit(gram_ref)


def _gram_kernel_path(x, y, *, interpret: bool, block=DEFAULT_BLOCK):
    n, p = x.shape[0], y.shape[0]
    bn, bp, bd = block
    xp = _pad_to(_pad_to(jnp.asarray(x, jnp.float32), bn, 0), bd, 1)
    yp = _pad_to(_pad_to(jnp.asarray(y, jnp.float32), bp, 0), bd, 1)
    out = gram_pallas(xp, yp, block=block, interpret=interpret)
    return out[:n, :p]


runtime.register_kernel_op(runtime.KernelImpl(
    name="gram",
    pallas=_gram_kernel_path,
    xla=lambda x, y, block=None: _gram_xla(x, y),
    ref=gram_ref,
))


def _gram_impl(x, y, block, interpret):
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _gram_xla(x, y)
    return _gram_kernel_path(x, y, interpret=d.interpret, block=block)


@jax.custom_vjp
def _gram_vjp(x, y):
    return _gram_impl(x, y, DEFAULT_BLOCK, None)


def _gram_fwd(x, y):
    return _gram_vjp(x, y), (x, y)


def _gram_bwd(res, g):
    x, y = res
    # d(X Y^T)/dX . g = g @ Y;  d/dY . g = g^T @ X — both are gram products
    return _gram_vjp(g, y.T), _gram_vjp(g.T, x.T)


_gram_vjp.defvjp(_gram_fwd, _gram_bwd)


def gram(x, y, *, block=DEFAULT_BLOCK, interpret: bool | None = None):
    """G = X @ Y^T via the Pallas kernel, any (n, d)/(p, d) shapes."""
    if block == DEFAULT_BLOCK and interpret is None:
        return _gram_vjp(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32))
    return _gram_impl(x, y, block, interpret)
