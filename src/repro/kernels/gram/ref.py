"""Pure-jnp oracle for the gram kernel."""
import jax.numpy as jnp


def gram_ref(x, y):
    """(n, d), (p, d) -> (n, p) fp32 inner products."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(y, jnp.float32).T
