"""One kernel runtime for every Pallas family: dispatch policy, registry,
persistent autotune cache, and the shape-sweep bench harness.

Before this module existed each family (``gram``, ``quant``, ``qgram``)
re-parsed ``REPRO_FORCE_PALLAS`` and treated ``interpret=None`` slightly
differently, and ``decode_attn`` had no XLA fallback at all.  The policy now
lives in exactly one place — :func:`choose` — and is identical for all
families:

* ``interpret`` given explicitly -> the Pallas kernel path with that
  interpret flag (the caller is debugging the kernel; policy stays out of
  the way).
* ``interpret=None`` on TPU -> compiled Pallas.
* ``interpret=None`` off-TPU with ``REPRO_FORCE_PALLAS=1`` -> interpret-mode
  Pallas (kernel checking only — on CPU the interpreter LOSES to XLA, see
  benchmarks/hotpath_bench.py).
* ``interpret=None`` otherwise (CPU, and GPU until a Triton lowering is
  registered) -> the family's single-jit XLA fallback.

Families register a :class:`KernelImpl` (pallas + xla entry points over the
SAME public signature, plus the ``ref.py`` oracle) so dispatch tables,
parity tests, and the bench sweep can enumerate every backend of every
family without knowing family internals.  docs/kernel_runtime.md documents
the policy, the cache file format, and how to add a backend.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import tempfile
import threading
import time
from typing import Any, Callable, Iterable, Sequence

import jax

from ..core.registry import Registry

# --------------------------------------------------------------------------
# the one fallback-policy code path
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Decision:
    """Outcome of the dispatch policy: which backend kind runs this call.

    ``kind`` is ``"pallas"`` or ``"xla"``; ``interpret`` is only meaningful
    for the Pallas kind."""

    kind: str
    interpret: bool = False


def force_pallas() -> bool:
    """True when ``REPRO_FORCE_PALLAS=1`` — the kernel path is forced even
    off-TPU (interpret mode; for checking kernels, never for speed)."""
    return os.environ.get("REPRO_FORCE_PALLAS", "") == "1"


def choose(interpret: bool | None = None) -> Decision:
    """THE fallback policy.  Every kernel family routes through this single
    function; see the module docstring for the table."""
    if interpret is not None:
        return Decision("pallas", bool(interpret))
    if jax.default_backend() == "tpu":
        return Decision("pallas", False)
    if force_pallas():
        return Decision("pallas", True)
    return Decision("xla")


# --------------------------------------------------------------------------
# kernel registry (mirrors core.registry: named specs, menu-on-typo)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """Per-backend implementations of one kernel op, all over the SAME
    public (unpadded) signature so they are interchangeable in dispatch
    tables, parity tests, and the bench sweep.

    ``pallas`` takes the public args plus a required ``interpret`` keyword
    and owns its padding; ``xla`` is the single-jit fallback program; ``ref``
    is the pure-jnp oracle from the family's ``ref.py`` (parity target, may
    coincide with ``xla``)."""

    name: str
    pallas: Callable  # (*args, interpret: bool, **kw)
    xla: Callable  # (*args, **kw)
    ref: Callable | None = None


KERNEL_OPS = Registry("kernel op")


def register_kernel_op(spec: KernelImpl) -> KernelImpl:
    return KERNEL_OPS.register(spec.name, spec)


def kernel_op(name: str) -> KernelImpl:
    return KERNEL_OPS.get(name)


def dispatch(name: str, interpret: bool | None = None):
    """Resolve (policy, callable) for one op under the unified policy.

    Returns ``(decision, fn)`` where ``fn`` already has the backend choice
    (and interpret flag, for Pallas) bound."""
    spec = KERNEL_OPS.get(name)
    d = choose(interpret)
    if d.kind == "xla":
        return d, spec.xla
    return d, functools.partial(spec.pallas, interpret=d.interpret)


# --------------------------------------------------------------------------
# autotune candidate registry (one menu per op family)
# --------------------------------------------------------------------------
#
# Families used to keep their candidate tables as private module constants,
# which meant a new shape family (the tenant-batched fleet epilogue) had no
# sanctioned place to declare what is worth sweeping.  Candidates now
# register next to the KernelImpl, at module top level, and every sweep
# (qgram's block autotune, the fleet epilogue's t-tile resolve) reads the
# same table.

_TUNE_CANDIDATES: dict[str, tuple] = {}


def register_tune_candidates(op: str, candidates: Iterable[tuple]) -> tuple:
    """Declare the autotune candidate set for one kernel op (module top
    level, like :func:`register_kernel_op`).  Re-registration replaces the
    menu — the persistent cache keys are shape-scoped, so stale winners that
    fall off the menu are ignored by :func:`autotune`'s membership check."""
    cands = tuple(tuple(c) for c in candidates)
    _TUNE_CANDIDATES[op] = cands
    return cands


def tune_candidates(op: str) -> tuple:
    """The registered candidate menu for ``op`` (KeyError names the menu on
    a typo, mirroring the registry convention)."""
    try:
        return _TUNE_CANDIDATES[op]
    except KeyError:
        raise KeyError(
            f"no autotune candidates registered for {op!r}: known are "
            f"{sorted(_TUNE_CANDIDATES)}"
        ) from None


def interpret_autotune() -> bool:
    """Normally sweeps only run on the compiled (TPU) path — timing the
    interpreter is meaningless.  REPRO_AUTOTUNE_INTERPRET=1 lets tests drive
    the full autotune round-trip (sweep -> persist -> warm hit) on CPU."""
    return os.environ.get("REPRO_AUTOTUNE_INTERPRET", "") == "1"


# --------------------------------------------------------------------------
# persistent autotune cache
# --------------------------------------------------------------------------
#
# File format (JSON, atomic-rename writes):
#   {"version": 1, "entries": {"<key>": [bn, bp], ...}}
# Key format (one string so the file stays greppable):
#   <op>|<backend>|<shape>x<shape>...|<dtype>|bits=<b>|<extra...>
# A corrupt, stale, or unreadable file is IGNORED (defaults / re-sweep), never
# an error: the cache is an accelerant, not a dependency.

CACHE_VERSION = 1

_SWEEPS = 0  # process-local count of sweeps actually run (tests assert on it)
_CACHE_MEM: dict[str, tuple] | None = None
_CACHE_LOCK = threading.Lock()


def cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "autotune.json"),
    )


def cache_key(
    op: str,
    shapes: Sequence[Sequence[int]],
    dtype: Any,
    bits: int | None = None,
    extra: Sequence[Any] = (),
) -> str:
    """Build the (shape, dtype, bits, backend) cache key for one op call."""
    shape_sig = "x".join("-".join(str(int(s)) for s in shp) for shp in shapes)
    parts = [op, jax.default_backend(), shape_sig, str(dtype)]
    if bits is not None:
        parts.append(f"bits={int(bits)}")
    parts.extend(str(e) for e in extra)
    return "|".join(parts)


def _load_cache() -> dict[str, tuple]:
    global _CACHE_MEM
    if _CACHE_MEM is not None:
        return _CACHE_MEM
    entries: dict[str, tuple] = {}
    try:
        with open(cache_path()) as f:
            blob = json.load(f)
        if (
            isinstance(blob, dict)
            and blob.get("version") == CACHE_VERSION
            and isinstance(blob.get("entries"), dict)
        ):
            for k, v in blob["entries"].items():
                if isinstance(k, str) and isinstance(v, (list, tuple)):
                    entries[k] = tuple(v)
    except (OSError, ValueError, TypeError):
        pass  # corrupt/stale/missing -> defaults; a later sweep rewrites it
    _CACHE_MEM = entries
    return entries


def _store_cache(key: str, value: tuple) -> None:
    entries = _load_cache()
    entries[key] = tuple(value)
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(path) or ".", prefix=".autotune-"
        )
        with os.fdopen(fd, "w") as f:
            json.dump(
                {
                    "version": CACHE_VERSION,
                    "entries": {k: list(v) for k, v in entries.items()},
                },
                f,
                indent=1,
                sort_keys=True,
            )
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS etc: stay in-process-only


def clear_cache_memory() -> None:
    """Drop the in-process cache image (tests poke the file between calls)."""
    global _CACHE_MEM
    with _CACHE_LOCK:
        _CACHE_MEM = None


def autotune(
    key: str,
    candidates: Iterable[tuple],
    measure: Callable[[tuple], float | None],
    default: tuple,
) -> tuple:
    """Warm-hit-or-sweep: return the cached winner for ``key`` if the disk /
    in-process cache has one, else time ``measure(candidate)`` over the
    candidates (``None`` = candidate infeasible for this shape), persist the
    winner, and return it.  A warm hit performs ZERO sweeps — asserted by
    tests/test_kernel_runtime.py across two processes."""
    global _SWEEPS
    cands = [tuple(c) for c in candidates]
    with _CACHE_LOCK:
        hit = _load_cache().get(key)
    if hit is not None and tuple(hit) in cands:
        return tuple(hit)
    _SWEEPS += 1
    best, best_t = tuple(default), float("inf")
    for cand in cands:
        try:
            dt = measure(cand)
        except Exception:
            continue
        if dt is not None and dt < best_t:
            best, best_t = cand, dt
    with _CACHE_LOCK:
        _store_cache(key, best)
    return best


def sweep_count() -> int:
    """Number of autotune sweeps this process has actually run."""
    return _SWEEPS


# --------------------------------------------------------------------------
# FlagGems-style shape sweep (benchmarks/kernels_bench.py wires this in)
# --------------------------------------------------------------------------


def timing_backends(spec: KernelImpl) -> dict[str, Callable]:
    """The backend table worth timing on this host: the XLA fallback always,
    plus the Pallas kernel (compiled on TPU, interpret elsewhere — labelled
    so the row is honest about what ran)."""
    interp = jax.default_backend() != "tpu"
    label = "pallas_interpret" if interp else "pallas"
    return {
        "xla": spec.xla,
        label: functools.partial(spec.pallas, interpret=interp),
    }


def shape_sweep(
    op: str,
    cases: Sequence[tuple[str, Callable[[], tuple], dict | None]],
    reps: int = 2,
) -> list[tuple[str, str, float]]:
    """Time every backend of ``op`` across a shape table.

    ``cases`` rows are ``(label, make_args, kwargs)`` where ``make_args``
    builds the positional args for the op's public signature.  Returns
    ``(case_label, backend, us_per_call)`` rows; a backend that cannot run a
    case yields ``nan`` rather than aborting the sweep."""
    spec = KERNEL_OPS.get(op)
    rows: list[tuple[str, str, float]] = []
    for label, make_args, kw in cases:
        args = make_args()
        kw = dict(kw or {})
        for bname, fn in timing_backends(spec).items():
            call = lambda: jax.block_until_ready(fn(*args, **kw))
            try:
                call()  # compile + warm
                t0 = time.perf_counter()
                for _ in range(reps):
                    call()
                us = (time.perf_counter() - t0) / reps * 1e6
            except Exception:
                us = float("nan")
            rows.append((label, bname, us))
    return rows
