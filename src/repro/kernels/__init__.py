"""Pallas TPU kernels for the paper's compute hot-spots.

gram/        tiled gram-block  G = X Y^T       (MXU)
quant/       per-symbol encode/decode (§4.2)   (VPU threshold-count / one-hot)
qgram/       fused dequantize + gram           (decode in VMEM, no HBM roundtrip)
decode_attn/ single-token GQA decode attention (online softmax over KV chunks,
             ring-cache masking via kpos)
epilogue/    fused Nyström serve epilogue      (cached apply + fusion moments,
             one launch per query batch)

Each has <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public wrapper,
padding + backend dispatch through runtime.choose) and ref.py (pure-jnp oracle
used by the shape/dtype-sweep allclose tests).  ``runtime`` is the shared
dispatch policy + registry + persistent autotune cache (docs/kernel_runtime.md).
"""
from . import runtime
from .gram import ops as gram_ops
from .quant import ops as quant_ops
from .qgram import ops as qgram_ops
from .decode_attn import ops as decode_attn_ops
from .epilogue import ops as epilogue_ops
