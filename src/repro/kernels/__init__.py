"""Pallas TPU kernels for the paper's compute hot-spots.

gram/        tiled gram-block  G = X Y^T       (MXU)
quant/       per-symbol encode/decode (§4.2)   (VPU threshold-count / one-hot)
qgram/       fused dequantize + gram           (decode in VMEM, no HBM roundtrip)
decode_attn/ single-token GQA decode attention (online softmax over KV chunks,
             ring-cache masking via kpos)

Each has <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public wrapper,
padding + interpret-mode selection) and ref.py (pure-jnp oracle used by the
shape/dtype-sweep allclose tests).
"""
from .gram import ops as gram_ops
from .quant import ops as quant_ops
from .qgram import ops as qgram_ops
from .decode_attn import ops as decode_attn_ops
