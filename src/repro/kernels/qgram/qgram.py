"""Pallas TPU kernel: FUSED dequantize + gram,  G = decode(codes) @ Y^T.

The unfused pipeline decodes the received per-symbol codes to x̂ in HBM and
then runs the gram matmul — paying an extra HBM write + read of the full
(n, d) fp32 reconstruction.  Here the (bn, bd) code tile is decoded straight
into VMEM registers and fed to the MXU, so x̂ never exists in HBM.  This is
the arithmetic-intensity optimization of EXPERIMENTS.md §Perf.

Grid (n/bn, p/bp, d/bd); decode chunk-loops the centroid axis like
kernels/quant; fp32 accumulator tile (bn, bp) revisited over k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (128, 128, 128)  # (bn, bp, bd)
DEFAULT_ECHUNK = 128


def _qgram_kernel(codes_ref, cents_ref, y_ref, o_ref, *, echunk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...]  # (bn, bd)
    n_chunks = cents_ref.shape[1] // echunk

    def body(c, acc):
        cents = cents_ref[:, pl.dslice(c * echunk, echunk)]  # (bd, echunk)
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, echunk), 2) + c * echunk
        onehot = (codes[:, :, None] == idx).astype(cents.dtype)
        return acc + jnp.sum(onehot * cents[None, :, :], axis=-1)

    xhat = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(codes.shape, dtype=jnp.float32)
    )  # (bn, bd) decoded in VMEM — never touches HBM
    o_ref[...] += jax.lax.dot_general(
        xhat,
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block", "echunk", "interpret"))
def qgram_pallas(codes, scaled_cents, y, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=False):
    """codes: (n, d) int32; scaled_cents: (d, C); y: (p, d) -> (n, p) fp32."""
    n, d = codes.shape
    p, _ = y.shape
    bn, bp, bd = block
    grid = (n // bn, p // bp, d // bd)
    return pl.pallas_call(
        functools.partial(_qgram_kernel, echunk=echunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, scaled_cents.shape[1]), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bp, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(codes, scaled_cents, y)
