"""Pallas TPU kernel: FUSED unpack + dequantize + gram from PACKED words.

The wire/at-rest representation of quantized data is the packed code plane
(``repro.core.jax_scheme.pack_codes``): each row's d codes concatenated at
their per-dimension widths into W = ceil(R/32) uint32 words.  This kernel
consumes that plane DIRECTLY — the (bn, W) word tile is unpacked with
shift/mask ops inside the block, decoded against the scaled centroid tables
by a chunked one-hot matmul, and fed to the MXU — so neither the int codes
nor the fp32 reconstruction ever exists in HBM.

Grid (n/bn, p/bp); d and W are NOT tiled (W is 1-2 words for paper rates,
d <= a few hundred), so each (i, j) program writes its output tile once —
no cross-step accumulator.  The per-dimension bit layout arrives as a small
``meta`` operand (word index / bit offset / width per dimension, possibly
traced); word selection is a static W-step select loop, not a dynamic
gather, so the kernel lowers on TPU as well as in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_PACKED = (128, 128)  # (bn, bp)
DEFAULT_ECHUNK = 128
_WORD = 32


def _qgram_packed_kernel(
    words_ref, meta_ref, cents_ref, y_ref, mask_ref, o_ref, *, echunk: int
):
    words = words_ref[...]  # (bn, W) uint32
    W = words.shape[1]
    word_idx = meta_ref[0, :]  # (d,) int32
    bit = meta_ref[1, :].astype(jnp.uint32)
    width = meta_ref[2, :].astype(jnp.uint32)

    # select each dimension's source word(s) with a static W-step select loop
    # (TPU-safe: no dynamic gather on the lane axis)
    lo_src = jnp.zeros((words.shape[0], word_idx.shape[0]), jnp.uint32)
    hi_src = jnp.zeros_like(lo_src)
    for k in range(W):
        col = words[:, k][:, None]  # (bn, 1)
        lo_src = jnp.where(word_idx[None, :] == k, col, lo_src)
        hi_src = jnp.where(word_idx[None, :] + 1 == k, col, hi_src)

    lo = lo_src >> bit[None, :]
    hi = jnp.where(
        bit[None, :] > 0,
        hi_src << (_WORD - jnp.maximum(bit, jnp.uint32(1)))[None, :],
        jnp.uint32(0),
    )
    full = jnp.uint32(0xFFFFFFFF)
    wmask = jnp.where(
        width >= _WORD,
        full,
        (jnp.uint32(1) << jnp.minimum(width, jnp.uint32(_WORD - 1)))
        - jnp.uint32(1),
    )
    codes = ((lo | hi) & wmask[None, :]).astype(jnp.int32)  # (bn, d) in VMEM

    # dequantize: chunked one-hot matmul against the scaled centroid tables
    n_chunks = cents_ref.shape[1] // echunk

    def body(c, acc):
        cents = cents_ref[:, pl.dslice(c * echunk, echunk)]  # (d, echunk)
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, echunk), 2) + c * echunk
        onehot = (codes[:, :, None] == idx).astype(cents.dtype)
        return acc + jnp.sum(onehot * cents[None, :, :], axis=-1)

    xhat = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(codes.shape, dtype=jnp.float32)
    )  # (bn, d) decoded in VMEM — codes and x̂ never touch HBM
    xhat = xhat * mask_ref[...]  # (bn, 1): masked rows contribute zero rows
    o_ref[...] = jax.lax.dot_general(
        xhat,
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block", "echunk", "interpret"))
def qgram_packed_pallas(
    words, meta, scaled_cents, y, mask, *, block=DEFAULT_BLOCK_PACKED,
    echunk=DEFAULT_ECHUNK, interpret=False,
):
    """words: (n, W) uint32 packed rows; meta: (3, d) int32 [word, bit, width]
    per dimension; scaled_cents: (d, C); y: (p, d); mask: (n, 1) row validity
    -> (n, p) fp32.  All shapes pre-padded to block multiples by the caller."""
    n, _ = words.shape
    p, _ = y.shape
    bn, bp = block
    grid = (n // bn, p // bp)
    return pl.pallas_call(
        functools.partial(_qgram_packed_kernel, echunk=echunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, words.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec(meta.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(scaled_cents.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((bp, y.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(words, meta, scaled_cents, y, mask)
