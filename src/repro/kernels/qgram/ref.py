"""Pure-jnp oracle for the fused dequantize+gram kernel."""
import jax.numpy as jnp


def qgram_ref(codes, scaled_cents, y):
    """decode then gram: G[i, j] = <cents[., codes[i, .]], y[j, .]>."""
    d = scaled_cents.shape[0]
    xhat = scaled_cents[jnp.arange(d), codes]  # (n, d)
    return xhat @ jnp.asarray(y, jnp.float32).T
