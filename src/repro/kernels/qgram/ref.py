"""Pure-jnp oracles for the fused dequantize+gram kernels."""
import jax.numpy as jnp

from ...core import jax_scheme


def qgram_ref(codes, scaled_cents, y):
    """decode then gram: G[i, j] = <cents[., codes[i, .]], y[j, .]>."""
    d = scaled_cents.shape[0]
    xhat = scaled_cents[jnp.arange(d), codes]  # (n, d)
    return xhat @ jnp.asarray(y, jnp.float32).T


def qgram_packed_ref(words, rates, scaled_cents, y, *, total_bits, mask=None):
    """Oracle for the packed path: unpack, decode, gram — three separate
    steps, every intermediate materialized."""
    codes = jax_scheme.unpack_codes(words, rates, total_bits=total_bits)
    d = scaled_cents.shape[0]
    xhat = scaled_cents[jnp.arange(d), codes]
    if mask is not None:
        xhat = xhat * jnp.asarray(mask, jnp.float32)[:, None]
    return xhat @ jnp.asarray(y, jnp.float32).T
