"""Public wrapper for the fused dequantize+gram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .qgram import qgram_pallas, DEFAULT_BLOCK, DEFAULT_ECHUNK


def _pad_axis(a, mult, axis, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def qgram(codes, scaled_cents, y, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=None):
    """G = decode(codes) @ y^T without materializing the reconstruction.

    codes: (n, d) int32 per-symbol codes; scaled_cents: (d, C) from
    repro.kernels.quant.ops.build_scaled_tables; y: (p, d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = codes.shape
    p = y.shape[0]
    bn, bp, bd = block
    # pad codes with an out-of-range code so padded dims decode to 0
    cpad = _pad_axis(_pad_axis(jnp.asarray(codes), bn, 0), bd, 1, value=-1)
    tpad = _pad_axis(_pad_axis(jnp.asarray(scaled_cents), bd, 0), echunk, 1)
    ypad = _pad_axis(_pad_axis(jnp.asarray(y, jnp.float32), bp, 0), bd, 1)
    out = qgram_pallas(cpad, tpad, ypad, block=block, echunk=echunk, interpret=interpret)
    return out[:n, :p]


def qgram_batched(codes, scaled_cents, y, **kw):
    """vmapped fused dequantize+gram over a leading machine axis.

    codes: (m, n, d) int32 (pad rows with -1 so they decode to 0);
    scaled_cents: (m, d, C) per-machine tables; y: (p, d) shared or (m, p, d)
    per-machine.  Returns (m, n, p)."""
    if y.ndim == 2:
        return jax.vmap(lambda c, t: qgram(c, t, y, **kw))(codes, scaled_cents)
    return jax.vmap(lambda c, t, yy: qgram(c, t, yy, **kw))(codes, scaled_cents, y)
