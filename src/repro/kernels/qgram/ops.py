"""Public wrappers for the fused dequantize+gram kernels.

Two entry points:

* :func:`qgram_packed` / :func:`qgram_packed_batched` — the PRIMARY path:
  consume the packed code plane (``jax_scheme.pack_codes`` uint32 words, the
  same buffer the collectives move and the checkpoints store) and fuse
  unpack + dequantize + gram in one tiled Pallas kernel (:mod:`.packed`).
* :func:`qgram` / :func:`qgram_batched` — the legacy unpacked-int-code API,
  kept for callers holding raw (n, d) int32 codes.

Backend selection is the unified runtime policy
(:func:`repro.kernels.runtime.choose`): compiled Pallas on TPU, the
equivalent single-jit XLA program elsewhere, ``interpret=True`` /
``REPRO_FORCE_PALLAS=1`` to force the kernel path for debugging.  On the
compiled path, block sizes are autotuned per (shape, dtype, bits, backend)
through the runtime's PERSISTENT cache (:func:`repro.kernels.runtime
.autotune`): the sweep runs once per key per cache file, warm processes pad
only to the cached winner instead of the largest tune candidate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from ...core import jax_scheme
from .. import runtime
from .qgram import qgram_pallas, DEFAULT_BLOCK, DEFAULT_ECHUNK
from .packed import qgram_packed_pallas, DEFAULT_BLOCK_PACKED
from .ref import qgram_ref, qgram_packed_ref


def _pad_axis(a, mult, axis, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


# --------------------------------------------------------------------------
# the packed plane: words straight from the wire/checkpoint
# --------------------------------------------------------------------------


import functools


@functools.partial(jax.jit, static_argnames=("total_bits", "has_mask"))
def _qgram_packed_xla(words, rates, scaled_cents, y, mask, total_bits, has_mask):
    """XLA fallback: the same unpack -> decode -> matmul as ONE jitted
    program (no intermediate dispatch, no HBM round-trip between stages)."""
    codes = jax_scheme.unpack_codes(words, rates, total_bits=total_bits)
    d = scaled_cents.shape[0]
    xhat = scaled_cents[jnp.arange(d), codes]  # (n, d)
    if has_mask:
        xhat = xhat * mask[:, None]
    return xhat @ jnp.asarray(y, jnp.float32).T


# candidate menu lives in the runtime's central registry (satellite of the
# fleet-epilogue work: every family's sweep table is declared next to its
# KernelImpl and enumerable from one place)
_TUNE_CANDIDATES = runtime.register_tune_candidates(
    "qgram_packed", ((128, 128), (256, 128), (128, 256), (256, 256))
)

# kept as a name (tests/benchmarks import it); the policy is runtime's
_interpret_autotune = runtime.interpret_autotune


def _padded_inputs(words, rates, scaled_cents, y, mask, echunk, bn, bp):
    """Pad every operand to the given block (rows masked to zero)."""
    n = words.shape[0]
    mask_col = (
        jnp.ones((n, 1), jnp.float32) if mask is None
        else jnp.asarray(mask, jnp.float32)[:, None]
    )
    wpad = _pad_axis(words, bn, 0)
    mpad = _pad_axis(mask_col, bn, 0)
    tpad = _pad_axis(_pad_axis(jnp.asarray(scaled_cents), 8, 0), echunk, 1)
    d_pad = tpad.shape[0]
    ypad = _pad_axis(_pad_axis(jnp.asarray(y, jnp.float32), bp, 0), d_pad, 1)
    meta = _pack_meta(rates, d_pad)
    return wpad, meta, tpad, ypad, mpad


def _autotune_block(words, rates, scaled_cents, y, mask, echunk, total_bits,
                    interpret):
    """Resolve the (bn, bp) block for this logical shape via the runtime's
    persistent cache: a warm hit (this process or any earlier one that wrote
    the cache file) returns immediately with ZERO sweeps; a miss times one
    compiled run of each candidate on max-candidate-padded inputs, persists
    the winner, and returns it."""
    key = runtime.cache_key(
        "qgram_packed",
        shapes=(words.shape, scaled_cents.shape, y.shape),
        dtype=words.dtype,
        bits=total_bits,
        extra=(f"echunk={echunk}",),
    )
    cands = runtime.tune_candidates("qgram_packed")
    max_bn = max(c[0] for c in cands)
    max_bp = max(c[1] for c in cands)
    padded = None  # built lazily: only a cache MISS pays the max-pad

    def measure(cand):
        nonlocal padded
        if padded is None:
            padded = _padded_inputs(
                words, rates, scaled_cents, y, mask, echunk, max_bn, max_bp
            )
        wpad, meta, tpad, ypad, mpad = padded
        bn, bp = cand
        if wpad.shape[0] % bn or ypad.shape[0] % bp:
            return None
        fn = lambda: qgram_packed_pallas(
            wpad, meta, tpad, ypad, mpad, block=(bn, bp), echunk=echunk,
            interpret=interpret,
        )
        jax.block_until_ready(fn())  # compile + warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    return runtime.autotune(key, cands, measure, DEFAULT_BLOCK_PACKED)


def _pack_meta(rates, d_pad):
    """(3, d_pad) int32 [word index, bit offset, width] rows for the kernel;
    padded dimensions get width 0 (they unpack to code 0 and decode to the
    zero-padded centroid rows)."""
    w = jnp.asarray(rates, jnp.int32)
    w = jnp.concatenate([w, jnp.zeros((d_pad - w.shape[0],), jnp.int32)])
    offs = jnp.cumsum(w) - w
    return jnp.stack([offs // 32, offs % 32, w])


def _qgram_packed_kernel_path(
    words, rates, scaled_cents, y, *, total_bits, interpret,
    mask=None, block=None, echunk=DEFAULT_ECHUNK,
):
    words = jnp.asarray(words)
    n, p = words.shape[0], y.shape[0]
    traced = any(
        isinstance(a, jax.core.Tracer)
        for a in (words, rates, scaled_cents, y)
        + (() if mask is None else (mask,))
    )
    autotune = (
        block is None and not traced and (not interpret or _interpret_autotune())
    )
    if autotune:
        bn, bp = _autotune_block(
            words, rates, scaled_cents, y, mask, echunk, total_bits, interpret
        )
    else:
        bn, bp = DEFAULT_BLOCK_PACKED if block is None else block
    # pad to the CHOSEN block only — the old path padded every autotuned call
    # to the largest tune candidate even when the cached winner was small
    wpad, meta, tpad, ypad, mpad = _padded_inputs(
        words, rates, scaled_cents, y, mask, echunk, bn, bp
    )
    out = qgram_packed_pallas(
        wpad, meta, tpad, ypad, mpad, block=(bn, bp), echunk=echunk,
        interpret=interpret,
    )
    return out[:n, :p]


def qgram_packed(
    words, rates, scaled_cents, y, *, total_bits: int, mask=None,
    block=None, echunk=DEFAULT_ECHUNK, interpret=None,
):
    """G = decode(unpack(words)) @ y^T straight from the packed code plane.

    words: (n, W) uint32 packed rows (``jax_scheme.pack_codes`` layout, W =
    ceil(total_bits/32)); rates: (d,) per-dimension widths (may be traced);
    scaled_cents: (d, C) from ``jax_scheme.scaled_centroids``; y: (p, d);
    mask: optional (n,) row validity — masked rows produce zero output rows
    (the packed twin of the old -1-sentinel behavior); total_bits: the static
    row bit budget the words were packed under."""
    words = jnp.asarray(words)
    d = runtime.choose(interpret)
    if words.shape[-1] == 0 or d.kind == "xla":
        # zero-rate rows have no words at all — nothing for a kernel block to
        # load; the XLA program handles the degenerate layout
        m = None if mask is None else jnp.asarray(mask, jnp.float32)
        return _qgram_packed_xla(
            words, rates, scaled_cents, y, m, total_bits, mask is not None
        )
    return _qgram_packed_kernel_path(
        words, rates, scaled_cents, y, total_bits=total_bits,
        interpret=d.interpret, mask=mask, block=block, echunk=echunk,
    )


def qgram_packed_batched(words, rates, scaled_cents, y, *, total_bits, mask=None, **kw):
    """vmapped :func:`qgram_packed` over a leading machine axis.

    words: (m, n, W); rates: (m, d); scaled_cents: (m, d, C); y: (p, d)
    shared or (m, p, d) per-machine; mask: optional (m, n).  Returns
    (m, n, p)."""
    run = lambda w, r, t, yy, mk: qgram_packed(
        w, r, t, yy, total_bits=total_bits, mask=mk, **kw
    )
    in_axes = (0, 0, 0, 0 if y.ndim == 3 else None, None if mask is None else 0)
    return jax.vmap(run, in_axes=in_axes)(words, rates, scaled_cents, y, mask)


# --------------------------------------------------------------------------
# legacy unpacked-int-code API
# --------------------------------------------------------------------------


@jax.jit
def _qgram_xla(codes, scaled_cents, y):
    d = scaled_cents.shape[0]
    xhat = jnp.where(
        codes >= 0, scaled_cents[jnp.arange(d), jnp.maximum(codes, 0)], 0.0
    )
    return xhat @ jnp.asarray(y, jnp.float32).T


def _qgram_kernel_path(codes, scaled_cents, y, *, interpret,
                       block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK):
    n, d = codes.shape
    p = y.shape[0]
    bn, bp, bd = block
    # pad codes with an out-of-range code so padded dims decode to 0
    cpad = _pad_axis(_pad_axis(jnp.asarray(codes), bn, 0), bd, 1, value=-1)
    tpad = _pad_axis(_pad_axis(jnp.asarray(scaled_cents), bd, 0), echunk, 1)
    ypad = _pad_axis(_pad_axis(jnp.asarray(y, jnp.float32), bp, 0), bd, 1)
    out = qgram_pallas(cpad, tpad, ypad, block=block, echunk=echunk, interpret=interpret)
    return out[:n, :p]


def qgram(codes, scaled_cents, y, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=None):
    """G = decode(codes) @ y^T without materializing the reconstruction.

    codes: (n, d) int32 per-symbol codes (-1 decodes to 0); scaled_cents:
    (d, C); y: (p, d).  Prefer :func:`qgram_packed` — it eats the wire's
    packed words directly."""
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _qgram_xla(jnp.asarray(codes), scaled_cents, y)
    return _qgram_kernel_path(
        codes, scaled_cents, y, interpret=d.interpret, block=block, echunk=echunk
    )


def qgram_batched(codes, scaled_cents, y, **kw):
    """vmapped fused dequantize+gram over a leading machine axis.

    codes: (m, n, d) int32 (pad rows with -1 so they decode to 0);
    scaled_cents: (m, d, C) per-machine tables; y: (p, d) shared or (m, p, d)
    per-machine.  Returns (m, n, p)."""
    if y.ndim == 2:
        return jax.vmap(lambda c, t: qgram(c, t, y, **kw))(codes, scaled_cents)
    return jax.vmap(lambda c, t, yy: qgram(c, t, yy, **kw))(codes, scaled_cents, y)


runtime.register_kernel_op(runtime.KernelImpl(
    name="qgram",
    pallas=_qgram_kernel_path,
    xla=lambda c, t, y, block=None, echunk=None: _qgram_xla(jnp.asarray(c), t, y),
    ref=qgram_ref,
))
runtime.register_kernel_op(runtime.KernelImpl(
    name="qgram_packed",
    pallas=_qgram_packed_kernel_path,
    xla=lambda w, r, t, y, *, total_bits, mask=None, block=None, echunk=None:
        _qgram_packed_xla(
            jnp.asarray(w), r, t, y,
            None if mask is None else jnp.asarray(mask, jnp.float32),
            total_bits, mask is not None,
        ),
    ref=qgram_packed_ref,
))
