"""Public wrappers for the fused dequantize+gram kernels.

Two entry points:

* :func:`qgram_packed` / :func:`qgram_packed_batched` — the PRIMARY path:
  consume the packed code plane (``jax_scheme.pack_codes`` uint32 words, the
  same buffer the collectives move and the checkpoints store) and fuse
  unpack + dequantize + gram in one tiled Pallas kernel
  (:mod:`.packed`).  Off-TPU the default routes to an equivalent single-jit
  XLA program instead of interpret-mode Pallas — interpret mode exists to
  CHECK the kernel, not to win benchmarks.  Pass ``interpret=True`` (or set
  ``REPRO_FORCE_PALLAS=1``) to force the Pallas kernel path anyway: compiled
  on TPU, interpret mode everywhere else — for kernel debugging, never for
  speed.  On TPU, block sizes are autotuned per shape
  (:func:`_autotune_block`, cached).
* :func:`qgram` / :func:`qgram_batched` — the legacy unpacked-int-code API,
  kept for callers holding raw (n, d) int32 codes; same backend policy.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

from ...core import jax_scheme
from .qgram import qgram_pallas, DEFAULT_BLOCK, DEFAULT_ECHUNK
from .packed import qgram_packed_pallas, DEFAULT_BLOCK_PACKED


def _pad_axis(a, mult, axis, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _use_pallas() -> bool:
    """Pallas kernel path on TPU (compiled) or when REPRO_FORCE_PALLAS=1
    (interpret mode off-TPU — kernel debugging only); the single-jit XLA
    fallback elsewhere.  On CPU the interpret-mode kernel LOSES to plain
    XLA, so it is never the default (benchmarks/hotpath_bench.py records
    the comparison)."""
    return jax.default_backend() == "tpu" or os.environ.get(
        "REPRO_FORCE_PALLAS", ""
    ) == "1"


# --------------------------------------------------------------------------
# the packed plane: words straight from the wire/checkpoint
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("total_bits", "has_mask"))
def _qgram_packed_xla(words, rates, scaled_cents, y, mask, total_bits, has_mask):
    """XLA fallback: the same unpack -> decode -> matmul as ONE jitted
    program (no intermediate dispatch, no HBM round-trip between stages)."""
    codes = jax_scheme.unpack_codes(words, rates, total_bits=total_bits)
    d = scaled_cents.shape[0]
    xhat = scaled_cents[jnp.arange(d), codes]  # (n, d)
    if has_mask:
        xhat = xhat * mask[:, None]
    return xhat @ jnp.asarray(y, jnp.float32).T


_TUNE_CACHE: dict = {}
_TUNE_CANDIDATES = ((128, 128), (256, 128), (128, 256), (256, 256))


def _autotune_block(words, meta, cents, y, mask, echunk):
    """Pick the fastest (bn, bp) for this shape by timing one compiled run of
    each candidate (TPU path only; cached per shape).  Under a trace (vmap/
    jit of the wrapper) there is nothing to time — fall back to the cached
    winner for this shape or the default block."""
    key = (words.shape, cents.shape, y.shape, echunk)
    if key in _TUNE_CACHE:
        return _TUNE_CACHE[key]
    if any(isinstance(a, jax.core.Tracer) for a in (words, meta, cents, y, mask)):
        return DEFAULT_BLOCK_PACKED
    best, best_t = DEFAULT_BLOCK_PACKED, float("inf")
    for bn, bp in _TUNE_CANDIDATES:
        if words.shape[0] % bn or y.shape[0] % bp:
            continue
        try:
            fn = lambda: qgram_packed_pallas(
                words, meta, cents, y, mask, block=(bn, bp), echunk=echunk
            )
            jax.block_until_ready(fn())  # compile + warm
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            dt = time.perf_counter() - t0
        except Exception:
            continue
        if dt < best_t:
            best, best_t = (bn, bp), dt
    _TUNE_CACHE[key] = best
    return best


def _pack_meta(rates, d_pad):
    """(3, d_pad) int32 [word index, bit offset, width] rows for the kernel;
    padded dimensions get width 0 (they unpack to code 0 and decode to the
    zero-padded centroid rows)."""
    w = jnp.asarray(rates, jnp.int32)
    w = jnp.concatenate([w, jnp.zeros((d_pad - w.shape[0],), jnp.int32)])
    offs = jnp.cumsum(w) - w
    return jnp.stack([offs // 32, offs % 32, w])


def qgram_packed(
    words, rates, scaled_cents, y, *, total_bits: int, mask=None,
    block=None, echunk=DEFAULT_ECHUNK, interpret=None,
):
    """G = decode(unpack(words)) @ y^T straight from the packed code plane.

    words: (n, W) uint32 packed rows (``jax_scheme.pack_codes`` layout, W =
    ceil(total_bits/32)); rates: (d,) per-dimension widths (may be traced);
    scaled_cents: (d, C) from ``jax_scheme.scaled_centroids``; y: (p, d);
    mask: optional (n,) row validity — masked rows produce zero output rows
    (the packed twin of the old -1-sentinel behavior); total_bits: the static
    row bit budget the words were packed under."""
    words = jnp.asarray(words)
    n = words.shape[0]
    p = y.shape[0]
    if words.shape[-1] == 0 or interpret is None:
        if words.shape[-1] == 0 or not _use_pallas():
            # zero-rate rows have no words at all — nothing for a kernel
            # block to load; the XLA program handles the degenerate layout
            m = None if mask is None else jnp.asarray(mask, jnp.float32)
            return _qgram_packed_xla(
                words, rates, scaled_cents, y, m, total_bits, mask is not None
            )
        interpret = jax.default_backend() != "tpu"
    autotune = block is None and not interpret
    bn, bp = DEFAULT_BLOCK_PACKED if block is None else block
    # when autotuning, pad to the LARGEST candidate block so every (bn, bp)
    # in the search space divides the shape and is actually reachable
    pad_n = max(c[0] for c in _TUNE_CANDIDATES) if autotune else bn
    pad_p = max(c[1] for c in _TUNE_CANDIDATES) if autotune else bp
    mask_col = (
        jnp.ones((n, 1), jnp.float32) if mask is None
        else jnp.asarray(mask, jnp.float32)[:, None]
    )
    wpad = _pad_axis(words, pad_n, 0)
    mpad = _pad_axis(mask_col, pad_n, 0)  # padded rows masked to zero
    tpad = _pad_axis(_pad_axis(jnp.asarray(scaled_cents), 8, 0), echunk, 1)
    d_pad = tpad.shape[0]
    ypad = _pad_axis(_pad_axis(jnp.asarray(y, jnp.float32), pad_p, 0), d_pad, 1)
    meta = _pack_meta(rates, d_pad)
    if autotune:
        bn, bp = _autotune_block(wpad, meta, tpad, ypad, mpad, echunk)
    out = qgram_packed_pallas(
        wpad, meta, tpad, ypad, mpad, block=(bn, bp), echunk=echunk,
        interpret=interpret,
    )
    return out[:n, :p]


def qgram_packed_batched(words, rates, scaled_cents, y, *, total_bits, mask=None, **kw):
    """vmapped :func:`qgram_packed` over a leading machine axis.

    words: (m, n, W); rates: (m, d); scaled_cents: (m, d, C); y: (p, d)
    shared or (m, p, d) per-machine; mask: optional (m, n).  Returns
    (m, n, p)."""
    run = lambda w, r, t, yy, mk: qgram_packed(
        w, r, t, yy, total_bits=total_bits, mask=mk, **kw
    )
    in_axes = (0, 0, 0, 0 if y.ndim == 3 else None, None if mask is None else 0)
    return jax.vmap(run, in_axes=in_axes)(words, rates, scaled_cents, y, mask)


# --------------------------------------------------------------------------
# legacy unpacked-int-code API
# --------------------------------------------------------------------------


@jax.jit
def _qgram_xla(codes, scaled_cents, y):
    d = scaled_cents.shape[0]
    xhat = jnp.where(
        codes >= 0, scaled_cents[jnp.arange(d), jnp.maximum(codes, 0)], 0.0
    )
    return xhat @ jnp.asarray(y, jnp.float32).T


def qgram(codes, scaled_cents, y, *, block=DEFAULT_BLOCK, echunk=DEFAULT_ECHUNK, interpret=None):
    """G = decode(codes) @ y^T without materializing the reconstruction.

    codes: (n, d) int32 per-symbol codes (-1 decodes to 0); scaled_cents:
    (d, C); y: (p, d).  Prefer :func:`qgram_packed` — it eats the wire's
    packed words directly."""
    if interpret is None:
        if not _use_pallas():
            return _qgram_xla(jnp.asarray(codes), scaled_cents, y)
        interpret = jax.default_backend() != "tpu"
    n, d = codes.shape
    p = y.shape[0]
    bn, bp, bd = block
    # pad codes with an out-of-range code so padded dims decode to 0
    cpad = _pad_axis(_pad_axis(jnp.asarray(codes), bn, 0), bd, 1, value=-1)
    tpad = _pad_axis(_pad_axis(jnp.asarray(scaled_cents), bd, 0), echunk, 1)
    ypad = _pad_axis(_pad_axis(jnp.asarray(y, jnp.float32), bp, 0), bd, 1)
    out = qgram_pallas(cpad, tpad, ypad, block=block, echunk=echunk, interpret=interpret)
    return out[:n, :p]


def qgram_batched(codes, scaled_cents, y, **kw):
    """vmapped fused dequantize+gram over a leading machine axis.

    codes: (m, n, d) int32 (pad rows with -1 so they decode to 0);
    scaled_cents: (m, d, C) per-machine tables; y: (p, d) shared or (m, p, d)
    per-machine.  Returns (m, n, p)."""
    if y.ndim == 2:
        return jax.vmap(lambda c, t: qgram(c, t, y, **kw))(codes, scaled_cents)
    return jax.vmap(lambda c, t, yy: qgram(c, t, yy, **kw))(codes, scaled_cents, y)
