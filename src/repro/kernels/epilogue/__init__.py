from .ops import epilogue_moments
from .ref import epilogue_moments_ref, EPILOGUE_FUSES

__all__ = ["epilogue_moments", "epilogue_moments_ref", "EPILOGUE_FUSES"]
