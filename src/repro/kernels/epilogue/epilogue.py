"""Pallas TPU kernel: fused Nyström serve epilogue (decode-attn pattern).

Grid (m,): one step per expert, streaming that expert's (t, K) cross-gram
tile and its K x K cached operands HBM->VMEM; the (ROWS, t) fp32 moment
accumulator lives in the revisited output tile across steps (the same
output-accumulator-only shape as the gram and decode_attn kernels).  Each
step runs the expert's cached apply — two MXU matmuls against ``Ainv`` and
the woodbury projector ``P`` — and folds the resulting predictive straight
into the fusion's moment rows, so the whole serve tail between the
cross-gram and ``finalize`` is ONE kernel launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# fp32 sublane tile: the (3, t) moment rows ride in an 8-row output block
ROWS = 8
LANE = 128


def _epilogue_kernel(g_ref, a_ref, p_ref, wa_ref, gss_ref, prior_ref, w_ref,
                     o_ref, *, fuse):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    G = g_ref[0]        # (t, K)
    A = a_ref[0]        # (K, K)  Ainv
    P = p_ref[0]        # (K, K)
    wa = wa_ref[0]      # (1, K)
    gss = gss_ref[...]  # (1, t)
    prior = prior_ref[...]
    w = w_ref[...]      # (1, t) — expert weight broadcast over test points

    # B^T = G Ainv^T : the triangular solve of nystrom_apply, cached as a matmul
    Bt = jax.lax.dot_general(
        G, A, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (t, K)
    mu = jnp.sum(Bt * wa, axis=1, keepdims=True).T  # (1, t)
    Q = jax.lax.dot_general(
        Bt, P, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (t, K) = B^T P  (P symmetric)
    quad = jnp.sum(Bt * Q, axis=1, keepdims=True).T
    s2 = jnp.maximum(gss - quad, 1e-12)  # expert predictive variance

    # fusion moment rows — MUST mirror FusionSpec.moments term for term
    if fuse == "none":
        r0, r1, r2 = mu, s2, w
    elif fuse == "kl":
        r0, r1, r2 = w * mu, w * (s2 + mu * mu), w
    elif fuse == "rbcm":
        beta = 0.5 * (jnp.log(prior) - jnp.log(s2)) * w
        r0, r1, r2 = beta / s2, beta * mu / s2, beta
    else:  # poe / gpoe / bcm share precision rows
        r0, r1, r2 = w / s2, w * mu / s2, w

    pad = jnp.zeros((ROWS - 3, mu.shape[1]), jnp.float32)
    o_ref[...] += jnp.concatenate([r0, r1, r2, pad], axis=0)


def _epilogue_fleet_kernel(g_ref, a_ref, p_ref, wa_ref, gss_ref, prior_ref,
                           w_ref, o_ref, *, fuse):
    # grid (T, t-tiles, m): expert axis innermost, so each tenant's output
    # tile is revisited across its m experts with the accumulator init at
    # the first expert — tenants NEVER share an accumulator row (summing
    # all T*m experts into one tile would fuse tenants together)
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    G = g_ref[0, 0]        # (bt, K)
    A = a_ref[0, 0]        # (K, K)  Ainv
    P = p_ref[0, 0]        # (K, K)
    wa = wa_ref[0, 0]      # (1, K)
    gss = gss_ref[0]       # (1, bt)
    prior = prior_ref[0]
    w = w_ref[0]           # (1, bt)

    Bt = jax.lax.dot_general(
        G, A, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bt, K)
    mu = jnp.sum(Bt * wa, axis=1, keepdims=True).T  # (1, bt)
    Q = jax.lax.dot_general(
        Bt, P, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    quad = jnp.sum(Bt * Q, axis=1, keepdims=True).T
    s2 = jnp.maximum(gss - quad, 1e-12)

    # fusion moment rows — MUST mirror FusionSpec.moments term for term
    if fuse == "none":
        r0, r1, r2 = mu, s2, w
    elif fuse == "kl":
        r0, r1, r2 = w * mu, w * (s2 + mu * mu), w
    elif fuse == "rbcm":
        beta = 0.5 * (jnp.log(prior) - jnp.log(s2)) * w
        r0, r1, r2 = beta / s2, beta * mu / s2, beta
    else:  # poe / gpoe / bcm share precision rows
        r0, r1, r2 = w / s2, w * mu / s2, w

    pad = jnp.zeros((ROWS - 3, mu.shape[1]), jnp.float32)
    o_ref[0] += jnp.concatenate([r0, r1, r2, pad], axis=0)


@functools.partial(jax.jit, static_argnames=("fuse", "block", "interpret"))
def epilogue_fleet_pallas(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                          block=None, interpret=False):
    """Tenant-batched fused serve epilogue: G (T, m, t, K); Ainv/P
    (T, m, K, K); walpha (T, m, 1, K); gss/prior (T, 1, t); w (T, m, t).
    t and K must be LANE-multiples (ops.py pads); ``block`` is the tuned
    t-tile (None = full t, must divide t).  Returns the (T, ROWS, t)
    accumulator; rows [:, :3] are each tenant's summed fusion moments."""
    T, m, t, K = G.shape
    bt = t if block is None else int(block)
    grid = (T, t // bt, m)
    return pl.pallas_call(
        functools.partial(_epilogue_fleet_kernel, fuse=fuse),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bt, K), lambda i, s, j: (i, j, s, 0)),
            pl.BlockSpec((1, 1, K, K), lambda i, s, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, K, K), lambda i, s, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, K), lambda i, s, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, bt), lambda i, s, j: (i, 0, s)),
            pl.BlockSpec((1, 1, bt), lambda i, s, j: (i, 0, s)),
            pl.BlockSpec((1, 1, bt), lambda i, s, j: (i, j, s)),
        ],
        out_specs=pl.BlockSpec((1, ROWS, bt), lambda i, s, j: (i, 0, s)),
        out_shape=jax.ShapeDtypeStruct((T, ROWS, t), jnp.float32),
        interpret=interpret,
    )(G, Ainv, P, walpha, gss, prior, w)


@functools.partial(jax.jit, static_argnames=("fuse", "interpret"))
def epilogue_pallas(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                    interpret=False):
    """G: (m, t, K); Ainv/P: (m, K, K); walpha: (m, 1, K); gss/prior: (1, t);
    w: (m, t).  t and K must be LANE-multiples (ops.py pads).  Returns the
    (ROWS, t) accumulator; rows 0..2 are the summed fusion moments S."""
    m, t, K = G.shape
    return pl.pallas_call(
        functools.partial(_epilogue_kernel, fuse=fuse),
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, t, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, K, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, K), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ROWS, t), jnp.float32),
        interpret=interpret,
    )(G, Ainv, P, walpha, gss, prior, w)
