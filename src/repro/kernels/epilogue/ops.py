"""Jit'd public wrapper for the fused serve epilogue: pads (t, K) to lane
multiples, routes backend selection through the unified kernel runtime, and
slices the moment rows back out.

Padding is harmless by construction: padded K columns of ``G``/``Ainv``/
``P``/``walpha`` are zero (so they contribute nothing to the matmuls) and
padded t columns carry ``gss = prior = 1`` (so the rbcm logs and PoE
precisions stay finite) — the caller only ever sees rows ``[:, :t]``.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from .. import runtime
from .epilogue import epilogue_pallas, epilogue_fleet_pallas, LANE
from .ref import (  # noqa: F401
    epilogue_moments_ref,
    epilogue_moments_fleet_ref,
    EPILOGUE_FUSES,
)

_epilogue_xla = functools.partial(jax.jit, static_argnames=("fuse",))(
    epilogue_moments_ref
)

_epilogue_fleet_xla = functools.partial(jax.jit, static_argnames=("fuse",))(
    epilogue_moments_fleet_ref
)


def _pad_to(a, mult, axis, value=0.0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _epilogue_kernel_path(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                          interpret: bool):
    m, t, K = G.shape
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    Gp = _pad_to(_pad_to(f32(G), LANE, 1), LANE, 2)
    Ap = _pad_to(_pad_to(f32(Ainv), LANE, 1), LANE, 2)
    Pp = _pad_to(_pad_to(f32(P), LANE, 1), LANE, 2)
    wap = _pad_to(f32(walpha)[:, None, :], LANE, 2)  # (m, 1, Kp)
    gssp = _pad_to(f32(gss)[None, :], LANE, 1, value=1.0)  # (1, tp)
    priorp = _pad_to(f32(prior)[None, :], LANE, 1, value=1.0)
    tp = gssp.shape[1]
    wp = f32(w)[:, None] * jnp.ones((m, tp), jnp.float32)  # (m, tp)
    S = epilogue_pallas(Gp, Ap, Pp, wap, gssp, priorp, wp,
                        fuse=fuse, interpret=interpret)
    return S[:3, :t]


runtime.register_kernel_op(runtime.KernelImpl(
    name="epilogue",
    pallas=_epilogue_kernel_path,
    xla=lambda G, Ainv, P, walpha, gss, prior, w, fuse: _epilogue_xla(
        G, Ainv, P, walpha, gss, prior, w, fuse=fuse
    ),
    ref=epilogue_moments_ref,
))


def epilogue_moments(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                     interpret: bool | None = None):
    """Summed fusion moment rows S (3, t) for a fleet of cached Nyström
    experts — the fused serve epilogue (see ref.py for operand shapes).
    Callers finish with the fusion's ``finalize(S, m, prior)``."""
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _epilogue_xla(G, Ainv, P, walpha, gss, prior, w, fuse=fuse)
    return _epilogue_kernel_path(
        G, Ainv, P, walpha, gss, prior, w, fuse=fuse, interpret=d.interpret
    )


# --------------------------------------------------------------------------
# tenant-batched ("fleet") epilogue: the same op with a leading tenant axis
# --------------------------------------------------------------------------

# the fleet shape family's sweep menu: candidate t-tiles for the kernel's
# test-point axis (a tile must divide the LANE-padded t; infeasible
# candidates are skipped by the measure closure)
runtime.register_tune_candidates(
    "epilogue_fleet", ((LANE,), (2 * LANE,), (4 * LANE,))
)


def _epilogue_fleet_kernel_path(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                                interpret: bool, block=None):
    T, m, t, K = G.shape
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    Gp = _pad_to(_pad_to(f32(G), LANE, 2), LANE, 3)
    Ap = _pad_to(_pad_to(f32(Ainv), LANE, 2), LANE, 3)
    Pp = _pad_to(_pad_to(f32(P), LANE, 2), LANE, 3)
    wap = _pad_to(f32(walpha)[:, :, None, :], LANE, 3)  # (T, m, 1, Kp)
    gssp = _pad_to(f32(gss)[:, None, :], LANE, 2, value=1.0)  # (T, 1, tp)
    priorp = _pad_to(f32(prior)[:, None, :], LANE, 2, value=1.0)
    tp = gssp.shape[2]
    wp = f32(w)[:, :, None] * jnp.ones((T, m, tp), jnp.float32)  # (T, m, tp)
    if block is not None and tp % int(block):
        block = None  # tuned tile from another shape bucket: full-t fallback
    S = epilogue_fleet_pallas(Gp, Ap, Pp, wap, gssp, priorp, wp,
                              fuse=fuse, block=block, interpret=interpret)
    return S[:, :3, :t]


runtime.register_kernel_op(runtime.KernelImpl(
    name="epilogue_fleet",
    pallas=_epilogue_fleet_kernel_path,
    xla=lambda G, Ainv, P, walpha, gss, prior, w, fuse: _epilogue_fleet_xla(
        G, Ainv, P, walpha, gss, prior, w, fuse=fuse
    ),
    ref=epilogue_moments_fleet_ref,
))


def fleet_epilogue_block(T: int, m: int, t: int, K: int, *, fuse: str = "kl",
                         interpret: bool | None = None):
    """Resolve the tuned t-tile for a fleet-shaped epilogue launch.

    This runs OUTSIDE any trace — the fleet predict jit takes the winner as
    a STATIC argument, which is what lets the sweep happen at all (inside
    the traced program the operands are tracers and timing is meaningless).
    Returns ``None`` (kernel default: full t) when the XLA fallback will
    serve the launch, or when sweeping is pointless (interpret mode without
    REPRO_AUTOTUNE_INTERPRET=1).  Misses sweep synthetic zero operands of
    the launch shape and persist the winner through the runtime's autotune
    cache, so fleet-shaped launches warm-hit across processes exactly like
    the single-tenant families."""
    d = runtime.choose(interpret)
    if d.kind != "pallas":
        return None
    if d.interpret and not runtime.interpret_autotune():
        return None
    tp = t + (-t) % LANE
    Kp = K + (-K) % LANE
    key = runtime.cache_key(
        "epilogue_fleet", shapes=((T, m, t, K),), dtype=jnp.float32,
        extra=(f"fuse={fuse}",),
    )
    ops = None  # built lazily: only a cache MISS pays the allocation

    def measure(cand):
        nonlocal ops
        (bt,) = cand
        if tp % bt:
            return None
        if ops is None:
            ops = (
                jnp.zeros((T, m, tp, Kp), jnp.float32),
                jnp.zeros((T, m, Kp, Kp), jnp.float32),
                jnp.zeros((T, m, Kp, Kp), jnp.float32),
                jnp.zeros((T, m, 1, Kp), jnp.float32),
                jnp.ones((T, 1, tp), jnp.float32),
                jnp.ones((T, 1, tp), jnp.float32),
                jnp.ones((T, m, tp), jnp.float32),
            )
        fn = lambda: epilogue_fleet_pallas(
            *ops, fuse=fuse, block=bt, interpret=d.interpret
        )
        jax.block_until_ready(fn())  # compile + warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    win = runtime.autotune(
        key, runtime.tune_candidates("epilogue_fleet"), measure, (LANE,)
    )
    bt = int(win[0])
    return bt if tp % bt == 0 else None


def epilogue_moments_fleet(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                           block=None, interpret: bool | None = None):
    """Per-tenant summed fusion moment rows S (T, 3, t) — the fused serve
    epilogue batched over a leading tenant axis (operand shapes in ref.py).
    ONE kernel launch covers the whole mixed-tenant micro-batch; callers
    finish with a vmapped ``finalize``.  ``block``: tuned t-tile from
    :func:`fleet_epilogue_block` (static; None = kernel default)."""
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _epilogue_fleet_xla(G, Ainv, P, walpha, gss, prior, w,
                                   fuse=fuse)
    return _epilogue_fleet_kernel_path(
        G, Ainv, P, walpha, gss, prior, w, fuse=fuse, interpret=d.interpret,
        block=block,
    )
