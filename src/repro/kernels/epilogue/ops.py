"""Jit'd public wrapper for the fused serve epilogue: pads (t, K) to lane
multiples, routes backend selection through the unified kernel runtime, and
slices the moment rows back out.

Padding is harmless by construction: padded K columns of ``G``/``Ainv``/
``P``/``walpha`` are zero (so they contribute nothing to the matmuls) and
padded t columns carry ``gss = prior = 1`` (so the rbcm logs and PoE
precisions stay finite) — the caller only ever sees rows ``[:, :t]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import runtime
from .epilogue import epilogue_pallas, LANE
from .ref import epilogue_moments_ref, EPILOGUE_FUSES  # noqa: F401

_epilogue_xla = functools.partial(jax.jit, static_argnames=("fuse",))(
    epilogue_moments_ref
)


def _pad_to(a, mult, axis, value=0.0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def _epilogue_kernel_path(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                          interpret: bool):
    m, t, K = G.shape
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    Gp = _pad_to(_pad_to(f32(G), LANE, 1), LANE, 2)
    Ap = _pad_to(_pad_to(f32(Ainv), LANE, 1), LANE, 2)
    Pp = _pad_to(_pad_to(f32(P), LANE, 1), LANE, 2)
    wap = _pad_to(f32(walpha)[:, None, :], LANE, 2)  # (m, 1, Kp)
    gssp = _pad_to(f32(gss)[None, :], LANE, 1, value=1.0)  # (1, tp)
    priorp = _pad_to(f32(prior)[None, :], LANE, 1, value=1.0)
    tp = gssp.shape[1]
    wp = f32(w)[:, None] * jnp.ones((m, tp), jnp.float32)  # (m, tp)
    S = epilogue_pallas(Gp, Ap, Pp, wap, gssp, priorp, wp,
                        fuse=fuse, interpret=interpret)
    return S[:3, :t]


runtime.register_kernel_op(runtime.KernelImpl(
    name="epilogue",
    pallas=_epilogue_kernel_path,
    xla=lambda G, Ainv, P, walpha, gss, prior, w, fuse: _epilogue_xla(
        G, Ainv, P, walpha, gss, prior, w, fuse=fuse
    ),
    ref=epilogue_moments_ref,
))


def epilogue_moments(G, Ainv, P, walpha, gss, prior, w, *, fuse,
                     interpret: bool | None = None):
    """Summed fusion moment rows S (3, t) for a fleet of cached Nyström
    experts — the fused serve epilogue (see ref.py for operand shapes).
    Callers finish with the fusion's ``finalize(S, m, prior)``."""
    d = runtime.choose(interpret)
    if d.kind == "xla":
        return _epilogue_xla(G, Ainv, P, walpha, gss, prior, w, fuse=fuse)
    return _epilogue_kernel_path(
        G, Ainv, P, walpha, gss, prior, w, fuse=fuse, interpret=d.interpret
    )
