"""Reference (pure-jnp) fused serve epilogue.

One op covers the whole post-gram serve tail for a fleet of Nyström experts:
per-expert cached apply (mean + variance against the ``nystrom_serve_cache``
operands) AND the fusion moment rows, summed across experts.  The caller
finishes with the method's ``finalize`` (a handful of elementwise flops) —
so the entire epilogue between the cross-gram and the fused (mu, s2) is one
kernel launch instead of m solve/apply/fuse dispatches.

Inputs (m experts, t test points, K retained columns):
  G      (m, t, K)  masked cross-covariances G_*K per expert
  Ainv   (m, K, K)  explicit L_KK^{-1} (nystrom_serve_cache)
  P      (m, K, K)  woodbury quad-form projector (U - U M^{-1} U) / s2
  walpha (m, K)     W alpha
  gss    (t,)       prior test variance k(x*, x*) (noise-free)
  prior  (t,)       fusion prior variance k(x*, x*) + noise ((r)bcm only)
  w      (m,)       availability weights (healthy fleet: all ones)

``fuse`` selects the moment rows (must match ``FusionSpec.moments`` exactly):
  none       [mu_i, s2_i, w]        (single expert; finalize is identity)
  kl         [w mu, w (s2 + mu^2), w]
  poe/gpoe/bcm  [w/s2, w mu/s2, w]
  rbcm       beta-folded precision rows
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["epilogue_moments_ref", "epilogue_moments_fleet_ref",
           "EPILOGUE_FUSES"]

EPILOGUE_FUSES = ("none", "kl", "poe", "gpoe", "bcm", "rbcm")


def _moment_rows(fuse, mu, s2, prior, w):
    """(m, t) per-expert predictives -> (m, 3, t) moment rows."""
    if fuse == "none":
        return jnp.stack([mu, s2, w], axis=1)
    if fuse == "kl":
        return jnp.stack([w * mu, w * (s2 + mu * mu), w], axis=1)
    if fuse == "rbcm":
        beta = 0.5 * (jnp.log(prior)[None, :] - jnp.log(s2)) * w
        return jnp.stack([beta / s2, beta * mu / s2, beta], axis=1)
    if fuse in ("poe", "gpoe", "bcm"):
        return jnp.stack([w / s2, w * mu / s2, w], axis=1)
    raise ValueError(
        f"unknown epilogue fuse {fuse!r}: known are {', '.join(EPILOGUE_FUSES)}"
    )


def epilogue_moments_ref(G, Ainv, P, walpha, gss, prior, w, *, fuse):
    """Summed moment rows S (3, t) of the fused serve epilogue."""
    Bt = jnp.einsum("mtk,mjk->mtj", G, Ainv)  # B^T = G Ainv^T  (m, t, K)
    mu = jnp.einsum("mtj,mj->mt", Bt, walpha)
    quad = jnp.einsum("mtj,mjk,mtk->mt", Bt, P, Bt)
    s2 = jnp.maximum(gss[None, :] - quad, 1e-12)
    wc = jnp.asarray(w, mu.dtype)[:, None] * jnp.ones_like(mu)
    return jnp.sum(_moment_rows(fuse, mu, s2, prior, wc), axis=0)


def epilogue_moments_fleet_ref(G, Ainv, P, walpha, gss, prior, w, *, fuse):
    """Tenant-batched twin of :func:`epilogue_moments_ref`: every operand
    carries a leading tenant axis T (``G (T, m, t, K)``, ``gss/prior
    (T, t)``, ``w (T, m)``) and the moment rows sum over each tenant's OWN
    m experts only — returns ``(T, 3, t)``.  One vmap of the single-tenant
    oracle; the pallas kernel must match this tenant for tenant."""
    fn = functools.partial(epilogue_moments_ref, fuse=fuse)
    return jax.vmap(fn)(G, Ainv, P, walpha, gss, prior, w)
