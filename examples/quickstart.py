"""Quickstart: the paper's machinery in 80 lines.

1. Two 'machines' hold Gaussian datasets X and Y.
2. Machine M_x compresses X with the per-symbol scheme (§4.2) at a few
   bits/sample and 'transmits' int codes.
3. Machine M_y reconstructs X̂ and computes the cross gram matrix — compare
   its distortion to the Theorem-1 optimum and to PCA-style reduction.
4. Train a distributed GP across 8 machines and compare with BCM/rBCM.
5. Fit once / serve many: checkpoint the fitted protocol artifact, reload it,
   serve queries from cached factors, and stream new points in.

Run:  python examples/quickstart.py  (PYTHONPATH=src if not installed)
"""
import tempfile

import numpy as np
import jax

from repro.core import PerSymbolScheme, DimReductionScheme, OptimalScheme
from repro.core import DGPConfig, DistributedGP
from repro.core.rate_distortion import distortion_for_rate
from repro.core.distortion import distortion_quadratic, second_moment
from repro.core import split_machines, train_gp

rng = np.random.default_rng(0)
d, n = 16, 2000
A = rng.normal(size=(d, d)); Qx = A @ A.T / d
B = rng.normal(size=(d, d)); Qy = B @ B.T / d
X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)

R = 48  # bits per sample = 3 bits/dim
print(f"== inner-product compression at {R} bits/sample ({R/d:.1f} bits/dim) ==")
print(f"zero-rate distortion: {np.trace(Qx @ Qy):.4f}")
print(f"theorem-1 optimum   : {distortion_for_rate(Qx, Qy, R):.4f}")

ps = PerSymbolScheme(R).fit(Qx, Qy)
codes = ps.encode(X)  # int codes — this is all that crosses the wire
Xh = ps.decode(codes)
print(f"per-symbol (§4.2)   : {float(distortion_quadratic(X, Xh, Qy)):.4f} "
      f"({ps.wire_bits(n)} wire bits vs {32 * d * n} for fp32)")

dr = DimReductionScheme(R // 16).fit(Qx, Qy)
print(f"dim-reduction (Thm3): {float(distortion_quadratic(X, dr.roundtrip(X), Qy)):.4f}")

print("\n== distributed GP regression, 8 machines ==")
W = rng.normal(size=(d, 2))
f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
Xt = rng.multivariate_normal(np.zeros(d), Qx, size=400).astype(np.float32)
yt = f(Xt)
sm = lambda mu: float(np.mean((yt - np.asarray(mu)) ** 2) / np.var(yt))

full = train_gp(X[:600], y[:600], kernel="se", steps=100)
print(f"full GP           smse={sm(full.predict(Xt)[0]):.4f}")
parts = split_machines(X[:600], y[:600], 8, jax.random.PRNGKey(0))
# one validated config per protocol point — everything else is est.fit/predict
for method in ("bcm", "rbcm"):
    est = DistributedGP(DGPConfig(protocol="poe", fusion=method, bits_per_sample=0,
                                  gram_mode="dense", steps=100))
    mu, _ = est.predict(est.fit(parts=parts), Xt)
    print(f"{method:5s} (zero rate) smse={sm(mu):.4f}")
for bits in (8, 32, 64):
    est = DistributedGP(DGPConfig(protocol="center", bits_per_sample=bits,
                                  gram_mode="direct", steps=100))
    m = est.fit(parts=parts)
    print(f"quantized GP R={bits:3d} smse={sm(est.predict(m, Xt)[0]):.4f} "
          f"(wire {m.wire_bits/1e3:.0f} kbit)")

print("\n== fit once / serve many ==")
# est.fit already returned the serving artifact: checkpoint it, reload, and
# serve — predictions from the loaded copy are bitwise identical.
with tempfile.TemporaryDirectory() as ckpt_dir:
    est.save(m, ckpt_dir)
    served = est.load(ckpt_dir)   # meta.json carries the DGPConfig
mu0, _ = est.predict(served, Xt)
print(f"loaded artifact     smse={sm(mu0):.4f} (bitwise-identical serve, "
      f"{served.wire_bits/1e3:.0f} kbit ledger)")
# stream 50 new points into machine 3: its FROZEN codebook re-encodes only
# the new symbols; factors grow by rank-k updates — no refit anywhere
Xn = rng.multivariate_normal(np.zeros(d), Qx, size=50).astype(np.float32)
yn = (f(Xn) + 0.05 * rng.normal(size=50)).astype(np.float32)
served = est.update(served, Xn, yn, machine=3)
print(f"after update(+50)   smse={sm(est.predict(served, Xt)[0]):.4f} "
      f"(ledger {served.wire_bits/1e3:.0f} kbit)")
