"""End-to-end driver for the paper's §6 experiment: SARCOS-scale distributed
GP regression, 1000 points over 40 machines, single-center + broadcast
protocols vs BCM/rBCM at several wire rates.

Run:  PYTHONPATH=src python examples/distributed_gp_sarcos.py [--machines 40]
"""
import argparse

import numpy as np
import jax

from repro.core import (
    split_machines, single_center_gp, broadcast_gp, poe_baseline, train_gp,
)
from repro.data import regression_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--machines", type=int, default=40)
    ap.add_argument("--kernel", default="se", choices=["se", "linear"])
    ap.add_argument("--rates", type=int, nargs="+", default=[8, 21, 42, 84])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--data-dir", default=None, help="directory with sarcos.npz (real data)")
    args = ap.parse_args()

    X, y, Xt, yt = regression_dataset("sarcos", data_dir=args.data_dir)
    Xt, yt = Xt[:500], yt[:500]
    d = X.shape[1]
    sm = lambda mu: float(np.mean((yt - np.asarray(mu)) ** 2) / np.var(yt))

    print(f"SARCOS-scale: n={X.shape[0]} d={d} machines={args.machines} kernel={args.kernel}")
    full = train_gp(X, y, kernel=args.kernel, steps=args.steps)
    print(f"full GP (all data at center)      smse={sm(full.predict(Xt)[0]):.4f}")

    parts = split_machines(X, y, args.machines, jax.random.PRNGKey(0))
    for method in ("poe", "bcm", "rbcm"):
        mu, _, _ = poe_baseline(parts, Xt, kernel=args.kernel, method=method, steps=args.steps)
        print(f"{method:4s} (zero-rate baseline)         smse={sm(mu):.4f}")

    for R in args.rates:
        m = single_center_gp(parts, R, kernel=args.kernel, steps=args.steps, gram_mode="direct")
        mu, _ = m.predict(Xt)
        print(f"single-center R={R:3d} ({R/d:4.1f} b/dim) smse={sm(mu):.4f} "
              f"wire={m.wire_bits/1e3:.0f} kbit")
        mu, s2, wire, _ = broadcast_gp(parts, R, Xt, kernel=args.kernel,
                                       steps=args.steps, gram_mode="direct")
        print(f"broadcast     R={R:3d} ({R/d:4.1f} b/dim) smse={sm(mu):.4f} "
              f"wire={wire/1e3:.0f} kbit")


if __name__ == "__main__":
    main()
