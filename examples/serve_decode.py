"""Serve a small model with batched requests: prefill each prompt, then decode
with the per-family cache machinery (ring caches for sliding-window layers,
recurrent state for ssm/hybrid).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --gen 24
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import make_decode_step
    from repro.models.steps import init_train_state
    from repro.models.decode import init_decode_state

    cfg = get_config(args.arch).reduced()
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    B = args.batch
    state = init_decode_state(cfg, B, args.prompt_len + args.gen)
    step = jax.jit(make_decode_step(cfg))

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab_size, jnp.int32)

    t0 = time.time()
    for p in range(args.prompt_len):
        nxt, state = step(params, state, prompts[:, p][:, None], jnp.int32(p))
    t_prefill = time.time() - t0

    out = [nxt]
    t0 = time.time()
    for g in range(args.gen - 1):
        nxt, state = step(params, state, nxt, jnp.int32(args.prompt_len + g))
        out.append(nxt)
    t_dec = time.time() - t0
    toks = np.asarray(jnp.concatenate(out, axis=1))

    print(f"arch={cfg.name} (reduced) batch={B}")
    print(f"prefill {args.prompt_len} tokens: {1e3*t_prefill:.0f} ms; "
          f"decode {args.gen-1} tokens: {1e3*t_dec/(args.gen-1):.1f} ms/tok")
    for b in range(B):
        print(f"request {b}: {toks[b].tolist()}")


if __name__ == "__main__":
    main()
