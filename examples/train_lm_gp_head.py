"""Train a ~100M-parameter transformer for a few hundred steps, then fit a
distributed GP readout on its features with the paper's quantized-gram
protocol — the framework-level integration of the paper's technique.

Stage 1: xlstm-125m (width-reduced to ~hundred-M params at full width on a
         real cluster; CPU here runs a reduced variant) on synthetic LM data.
Stage 2: take penultimate-layer features for a probe task, split them across
         simulated machines, and compare full / rBCM / quantized-gram GP
         readouts (this is exactly the paper's setting with x := features).

Run:  PYTHONPATH=src python examples/train_lm_gp_head.py --steps 200
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--bits", type=int, nargs="+", default=[16, 64])
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import make_train_step, forward
    from repro.models.steps import init_train_state
    from repro.data import lm_batch_stream
    from repro.core import split_machines, single_center_gp, poe_baseline, train_gp

    cfg = get_config(args.arch).reduced()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"stage 1: train {cfg.name} ({n_params/1e6:.1f}M params reduced) "
          f"for {args.steps} steps")
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup=20, total_steps=args.steps))
    stream = lm_batch_stream(cfg.vocab_size, args.batch, args.seq, seed=0)
    for i in range(args.steps):
        params, opt, m = step(params, opt, next(stream))
        if (i + 1) % 50 == 0:
            print(f"  step {i+1:4d} loss {float(m['loss']):.4f}")

    print("stage 2: distributed GP readout on backbone features")
    # feature: 16-dim random projection of mean-pooled logits;
    # probe target: mean next-token entropy (both computable per machine)
    key = jax.random.PRNGKey(7)
    proj = jax.random.normal(key, (cfg.vocab_size, 16)) / np.sqrt(cfg.vocab_size)

    @jax.jit
    def feat_fn(batch):
        logits, _ = forward(params, cfg, batch, kind="prefill")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ent = -jnp.sum(jnp.exp(logp) * logp, -1)
        f = jnp.mean(logits.astype(jnp.float32), axis=1) @ proj
        return f, jnp.mean(ent, axis=1)

    Xs, ys = [], []
    for _ in range(40):
        f, t = feat_fn(next(stream))
        Xs.append(np.asarray(f)); ys.append(np.asarray(t))
    X = np.concatenate(Xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.float32)
    y = (y - y.mean()).astype(np.float32)
    X = ((X - X.mean(0)) / (X.std(0) + 1e-6)).astype(np.float32)
    n_tr = int(0.8 * len(y))
    Xt, yt = X[n_tr:], y[n_tr:]
    X, y = X[:n_tr], y[:n_tr]
    sm = lambda mu: float(np.mean((yt - np.asarray(mu)) ** 2) / max(np.var(yt), 1e-9))

    full = train_gp(X, y, kernel="se", steps=100)
    print(f"  full GP readout        smse={sm(full.predict(jnp.asarray(Xt))[0]):.4f}")
    parts = split_machines(X, y, 8, jax.random.PRNGKey(1))
    mu, _, _ = poe_baseline(parts, jnp.asarray(Xt), kernel="se", method="rbcm", steps=100)
    print(f"  rBCM (zero rate)       smse={sm(mu):.4f}")
    for bits in args.bits:
        m = single_center_gp(parts, bits, kernel="se", steps=100, gram_mode="direct")
        print(f"  quantized-gram R={bits:3d}   smse={sm(m.predict(jnp.asarray(Xt))[0]):.4f} "
              f"wire={m.wire_bits/1e3:.0f} kbit")


if __name__ == "__main__":
    main()
